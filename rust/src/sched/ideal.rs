//! Idealized zero-overhead FIFO scheduler — the correctness reference.
//!
//! Dispatch, launch and completion are free; T_total for N constant
//! t-second tasks on P slots is exactly `ceil(N/P) · t` and utilization
//! is 1 when N divides P. Property tests compare the real simulators
//! against this floor.

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::{ClusterSpec, SlotPool};
use crate::sim::{EventQueue, SimEv, SimScratch};
use crate::util::stats::Summary;
use crate::workload::{TraceRecord, Workload};
use std::collections::VecDeque;

/// The ideal zero-overhead scheduler.
pub struct IdealFifo;

impl Scheduler for IdealFifo {
    fn name(&self) -> &'static str {
        "IdealFIFO"
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        _seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let n = workload.len();
        scratch.begin(cluster, n, options.collect_trace);
        let SimScratch {
            queue: q,
            pending,
            pool,
            slot_mem,
            trace,
            ..
        } = scratch;
        pending.extend(0..n as u32);
        let mut makespan: f64 = 0.0;
        let mut waits = Summary::new();

        // Fill every slot at t=0; refill instantly on completion.
        let dispatch = |now: f64,
                            pending: &mut VecDeque<u32>,
                            pool: &mut SlotPool,
                            q: &mut EventQueue<SimEv>,
                            slot_mem: &mut [i64],
                            waits: &mut Summary,
                            trace: &mut Vec<TraceRecord>| {
            while let Some(&task_id) = pending.front() {
                let task = &workload.tasks[task_id as usize];
                let Some(slot) = pool.alloc(task.mem_mb) else {
                    break;
                };
                pending.pop_front();
                slot_mem[slot as usize] = task.mem_mb;
                waits.add(now - task.submit_at);
                if options.collect_trace {
                    trace.push(TraceRecord {
                        task: task_id,
                        node: pool.node_of(slot),
                        slot,
                        submit: task.submit_at,
                        start: now,
                        end: now + task.duration,
                    });
                }
                q.push(now + task.duration, SimEv::End { task: task_id, slot });
            }
        };

        dispatch(
            0.0,
            &mut *pending,
            &mut *pool,
            &mut *q,
            slot_mem.as_mut_slice(),
            &mut waits,
            &mut *trace,
        );
        while let Some((now, SimEv::End { slot, .. })) = q.pop() {
            makespan = makespan.max(now);
            pool.release(slot, slot_mem[slot as usize]);
            dispatch(
                now,
                &mut *pending,
                &mut *pool,
                &mut *q,
                slot_mem.as_mut_slice(),
                &mut waits,
                &mut *trace,
            );
        }

        let processors = cluster.total_cores();
        let events = q.popped();
        RunResult {
            scheduler: "IdealFIFO".into(),
            workload: workload.label.clone(),
            n_tasks: n as u64,
            processors,
            t_total: makespan,
            t_job: workload.t_job_per_proc(processors),
            events,
            daemon_busy: 0.0,
            waits,
            trace: options.collect_trace.then(|| std::mem::take(trace)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadBuilder;

    #[test]
    fn exact_makespan_and_full_utilization() {
        let cluster = ClusterSpec::homogeneous(2, 8, 32 * 1024, 2);
        // N = 64 tasks of 3 s on 16 slots -> 4 waves -> exactly 12 s.
        let w = WorkloadBuilder::constant(3.0).tasks(64).label("i").build();
        let r = IdealFifo.run(&w, &cluster, 0, &RunOptions::default());
        assert!((r.t_total - 12.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        assert!((r.delta_t()).abs() < 1e-9);
    }

    #[test]
    fn ragged_last_wave() {
        let cluster = ClusterSpec::homogeneous(1, 4, 32 * 1024, 1);
        // 6 tasks of 2 s on 4 slots -> waves of 4 then 2 -> 4 s.
        let w = WorkloadBuilder::constant(2.0).tasks(6).build();
        let r = IdealFifo.run(&w, &cluster, 0, &RunOptions::default());
        assert!((r.t_total - 4.0).abs() < 1e-9);
        // U = (12/4) / 4 = 0.75
        assert!((r.utilization() - 0.75).abs() < 1e-9);
    }
}
