//! Batch-queue scheduling with pluggable queue-management policies and
//! synchronously-parallel jobs.
//!
//! The latency benchmark (Table 9) uses 1-core array tasks; this module
//! covers the rest of the paper's §3.2.3/§3.2.5 feature space — the
//! machinery "essential when systems have a very deep set of pending
//! jobs in queues and there are expectations ... of 90% or higher
//! utilization":
//!
//! * **FCFS** — strict arrival order (head-of-line blocking included);
//! * **Priority** — static job priorities, then arrival order;
//! * **Fairshare** — users with less accumulated usage go first;
//! * **EASY backfill** — when the head job cannot start, reserve its
//!   earliest feasible start time and let smaller jobs jump ahead only
//!   if they cannot delay that reservation.
//!
//! Jobs here are rigid parallel jobs (need `cores` slots simultaneously,
//! all started together — "gang" launch), the workload class Figure 2
//! labels "parallel jobs".
//!
//! Since the kernel refactor this module is a [`SchedPolicy`] like the
//! others: the event loop, multi-core slot packing and wait/trace
//! accounting live in [`crate::sim::Kernel`]; and since the combinator
//! extraction the queue ordering and EASY backfill live in
//! [`crate::sched::combinators`] ([`OrderedDrain`]) — this file only
//! maps [`BatchJob`]s onto kernel tasks and keeps the per-run
//! running/usage state. The regression tests in `combinators` pin the
//! extracted drain bit-identical to the historical in-module one. The
//! simulator stays zero-overhead (it isolates *policy* effects; latency
//! effects live in the Table 9 simulators).

use crate::cluster::ClusterSpec;
use crate::sched::combinators::{FairTracker, Order, OrderedDrain};
use crate::sched::RunOptions;
use crate::sim::{Kernel, KernelCtx, Launch, SchedPolicy, SimScratch, Time};
use crate::util::stats::Summary;
use crate::workload::{TaskId, TaskSpec, Workload};

/// Queue-management policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueuePolicy {
    /// First-come first-served.
    Fcfs,
    /// FCFS with EASY backfill.
    FcfsBackfill,
    /// Static priority (higher first), FCFS within a priority level.
    Priority,
    /// Fair share across users: least accumulated core-seconds first.
    Fairshare,
}

/// A rigid (possibly parallel) batch job.
#[derive(Clone, Debug)]
pub struct BatchJob {
    /// Dense id.
    pub id: u32,
    /// Owning user (for fairshare).
    pub user: u32,
    /// Cores required simultaneously.
    pub cores: u32,
    /// Runtime once started (s). Also used as the (exact) runtime
    /// estimate for backfill reservations.
    pub duration: f64,
    /// Static priority (higher = sooner) for `QueuePolicy::Priority`.
    pub priority: i32,
    /// Submission time.
    pub submit_at: f64,
}

/// Per-job outcome.
#[derive(Clone, Copy, Debug)]
pub struct JobOutcome {
    /// Job id.
    pub id: u32,
    /// Start time.
    pub start: f64,
    /// End time.
    pub end: f64,
}

impl JobOutcome {
    /// Queue wait.
    pub fn wait(&self, submit: f64) -> f64 {
        self.start - submit
    }
}

/// Result of a batch-queue simulation.
#[derive(Clone, Debug)]
pub struct BatchRunResult {
    /// Makespan.
    pub makespan: f64,
    /// Core-seconds of useful work.
    pub work: f64,
    /// Utilization = work / (makespan · total cores).
    pub utilization: f64,
    /// Wait-time summary.
    pub waits: Summary,
    /// Per-job outcomes (indexed by job id).
    pub outcomes: Vec<JobOutcome>,
}

/// Batch-queue simulator (virtual time, zero scheduler overhead).
pub struct BatchQueueSim {
    policy: QueuePolicy,
}

/// The ordering/backfill policy driven by the kernel: dispatch
/// opportunities arise at submission, on arrivals, and on slot release.
/// Ordering and backfill decisions are delegated to the shared
/// [`OrderedDrain`] combinator.
struct BatchPolicy {
    drain: OrderedDrain,
    usage: FairTracker,
    /// Running set `(end_time, cores, job index)` for backfill shadows.
    running: Vec<(f64, u32, u32)>,
}

impl BatchPolicy {
    /// One policy-ordered dispatch pass over the pending queue.
    fn pass(&mut self, ctx: &mut KernelCtx, now: Time) {
        self.drain.drain(
            ctx,
            now,
            &mut self.usage,
            &mut self.running,
            &mut |_, _| Launch::start(now),
        );
    }
}

impl QueuePolicy {
    /// The combinator expressing this queue-management policy.
    fn as_drain(self) -> OrderedDrain {
        match self {
            QueuePolicy::Fcfs => OrderedDrain {
                order: Order::Fifo,
                backfill: false,
            },
            QueuePolicy::FcfsBackfill => OrderedDrain {
                order: Order::Fifo,
                backfill: true,
            },
            QueuePolicy::Priority => OrderedDrain {
                order: Order::Priority,
                backfill: false,
            },
            QueuePolicy::Fairshare => OrderedDrain {
                order: Order::Fairshare,
                backfill: false,
            },
        }
    }
}

impl SchedPolicy for BatchPolicy {
    fn label(&self) -> String {
        "BatchQueue".into()
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
        self.pass(ctx, 0.0);
    }

    fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, _task: TaskId) {
        // Defer until every same-instant arrival/release has landed:
        // backfill reservations must see the completed instant, exactly
        // as the pre-kernel decision-instant loop did.
        if !ctx.has_more_events_at(now) {
            self.pass(ctx, now);
        }
    }

    fn on_complete(
        &mut self,
        _ctx: &mut KernelCtx,
        now: Time,
        task: TaskId,
        _slot: u32,
    ) -> Option<Time> {
        self.running.retain(|&(_, _, t)| t != task);
        Some(now) // zero teardown: slots are reusable instantly
    }

    fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
        if !ctx.has_more_events_at(now) {
            self.pass(ctx, now);
        }
    }

    fn on_node_fail(&mut self, ctx: &mut KernelCtx, now: Time, _node: crate::cluster::NodeId) {
        // Killed tasks re-enter the queue through the normal ordering
        // (a retry keeps its job's priority and fairshare usage); the
        // queue is event-driven, so give it the dispatch pass a
        // release would have triggered. Stale backfill shadows from
        // the killed runs only skew reservation estimates until the
        // retries land — the shadows were estimates already.
        if !ctx.has_more_events_at(now) {
            self.pass(ctx, now);
        }
    }

    fn on_node_suspected(
        &mut self,
        ctx: &mut KernelCtx,
        now: Time,
        _node: crate::cluster::NodeId,
    ) {
        // Late detection looks exactly like the failure itself from the
        // queue's side: killed tasks are already requeued, so run the
        // dispatch pass a release would have triggered.
        if !ctx.has_more_events_at(now) {
            self.pass(ctx, now);
        }
    }

    fn on_node_drain(&mut self, ctx: &mut KernelCtx, now: Time, _node: crate::cluster::NodeId) {
        // A drain frees nothing and requeues nothing, but the
        // decision-instant discipline (see `on_arrive`) defers the
        // dispatch pass to the LAST same-instant event — which this
        // may be when a plan drains and fails nodes at one timestamp.
        if !ctx.has_more_events_at(now) {
            self.pass(ctx, now);
        }
    }

    fn on_node_recover(&mut self, ctx: &mut KernelCtx, now: Time, _node: crate::cluster::NodeId) {
        // Restored slots re-enter the pool without SlotFree events.
        if !ctx.has_more_events_at(now) {
            self.pass(ctx, now);
        }
    }
}

impl BatchQueueSim {
    /// New simulator with a policy.
    pub fn new(policy: QueuePolicy) -> Self {
        Self { policy }
    }

    /// Simulate `jobs` on `cluster` with a fresh scratch (allocating).
    /// Jobs must fit the cluster (cores <= total cores) or they are
    /// rejected with an error.
    pub fn run(&self, jobs: &[BatchJob], cluster: &ClusterSpec) -> Result<BatchRunResult, String> {
        self.run_with_scratch(jobs, cluster, &mut SimScratch::new())
    }

    /// Simulate `jobs` reusing `scratch`'s warm buffers (bit-identical
    /// to [`BatchQueueSim::run`]).
    pub fn run_with_scratch(
        &self,
        jobs: &[BatchJob],
        cluster: &ClusterSpec,
        scratch: &mut SimScratch,
    ) -> Result<BatchRunResult, String> {
        let total_cores = cluster.total_cores() as u32;
        for j in jobs {
            if j.cores == 0 || j.cores > total_cores {
                return Err(format!(
                    "job {} needs {} cores; cluster has {total_cores}",
                    j.id, j.cores
                ));
            }
            if !(j.duration.is_finite() && j.duration >= 0.0) {
                return Err(format!("job {} has invalid duration", j.id));
            }
            if !j.submit_at.is_finite() || j.submit_at < 0.0 {
                return Err(format!("job {} has invalid submit time", j.id));
            }
        }
        if jobs.is_empty() {
            return Ok(BatchRunResult {
                makespan: 0.0,
                work: 0.0,
                utilization: 1.0,
                waits: Summary::new(),
                outcomes: Vec::new(),
            });
        }

        // View the rigid jobs as multi-core kernel tasks. Memory is a
        // nominal 1 MB: batch-queue policy effects are core-count-only.
        let tasks: Vec<TaskSpec> = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| {
                let mut t = TaskSpec::array(i as u32, i as u32, j.duration);
                t.cores = j.cores;
                t.mem_mb = 1;
                t.submit_at = j.submit_at;
                t.priority = j.priority;
                t.user = j.user;
                t
            })
            .collect();
        let workload = Workload {
            tasks,
            label: "batchq".into(),
        };
        let mut policy = BatchPolicy {
            drain: self.policy.as_drain(),
            usage: FairTracker::new(),
            running: Vec::new(),
        };
        let r = Kernel::run(
            &mut policy,
            &workload,
            cluster,
            &RunOptions::with_trace(),
            scratch,
        );

        let trace = r.trace.as_ref().expect("batchq runs collect traces");
        let mut outcomes = vec![
            JobOutcome {
                id: 0,
                start: 0.0,
                end: 0.0
            };
            jobs.len()
        ];
        for rec in trace {
            outcomes[rec.task as usize] = JobOutcome {
                id: jobs[rec.task as usize].id,
                start: rec.start,
                end: rec.end,
            };
        }
        let work: f64 = jobs.iter().map(|j| j.cores as f64 * j.duration).sum();
        Ok(BatchRunResult {
            makespan: r.t_total,
            work,
            utilization: if r.t_total > 0.0 {
                work / (r.t_total * total_cores as f64)
            } else {
                1.0
            },
            waits: r.waits,
            outcomes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(cores: u32) -> ClusterSpec {
        ClusterSpec::homogeneous(1, cores, 1 << 20, 1)
    }

    fn job(id: u32, cores: u32, duration: f64) -> BatchJob {
        BatchJob {
            id,
            user: 0,
            cores,
            duration,
            priority: 0,
            submit_at: 0.0,
        }
    }

    #[test]
    fn fcfs_head_of_line_blocks() {
        // j0 takes all 8 cores for 10 s; j1 big waits; j2 small waits
        // behind j1 under strict FCFS.
        let jobs = vec![job(0, 8, 10.0), job(1, 8, 10.0), job(2, 1, 1.0)];
        let r = BatchQueueSim::new(QueuePolicy::Fcfs)
            .run(&jobs, &cluster(8))
            .unwrap();
        // Strict order: 0 → 1 → 2.
        assert_eq!(r.outcomes[2].start, 20.0);
        assert_eq!(r.makespan, 21.0);
    }

    #[test]
    fn backfill_fills_holes_without_delaying_head() {
        // 8 cores. j0: 4 cores 10 s (starts now). j1: 8 cores (head,
        // must wait until t=10). j2: 4 cores 5 s — fits NOW in the hole
        // and ends before j1's reservation: backfilled.
        let jobs = vec![job(0, 4, 10.0), job(1, 8, 10.0), job(2, 4, 5.0)];
        let r = BatchQueueSim::new(QueuePolicy::FcfsBackfill)
            .run(&jobs, &cluster(8))
            .unwrap();
        assert_eq!(r.outcomes[2].start, 0.0, "j2 should backfill");
        assert_eq!(r.outcomes[1].start, 10.0, "head must not be delayed");
        // FCFS for comparison: j2 waits until after j1.
        let f = BatchQueueSim::new(QueuePolicy::Fcfs)
            .run(&jobs, &cluster(8))
            .unwrap();
        assert!(f.outcomes[2].start >= 20.0);
        assert!(r.utilization > f.utilization);
    }

    #[test]
    fn backfill_rejects_delaying_jobs() {
        // j2 would run 20 s > shadow window (10 s) and needs cores the
        // head will use: must NOT backfill.
        let jobs = vec![job(0, 4, 10.0), job(1, 8, 10.0), job(2, 4, 20.0)];
        let r = BatchQueueSim::new(QueuePolicy::FcfsBackfill)
            .run(&jobs, &cluster(8))
            .unwrap();
        assert_eq!(r.outcomes[1].start, 10.0, "head on time");
        assert!(r.outcomes[2].start >= 10.0, "j2 must not jump");
    }

    #[test]
    fn priority_orders_queue() {
        let mut jobs = vec![job(0, 8, 5.0), job(1, 8, 5.0), job(2, 8, 5.0)];
        jobs[2].priority = 10;
        let r = BatchQueueSim::new(QueuePolicy::Priority)
            .run(&jobs, &cluster(8))
            .unwrap();
        // All arrive at t=0: j2 (priority 10) runs first, then FCFS j0, j1.
        assert_eq!(r.outcomes[2].start, 0.0);
        assert_eq!(r.outcomes[0].start, 5.0);
        assert_eq!(r.outcomes[1].start, 10.0);
    }

    #[test]
    fn fairshare_alternates_users() {
        let mut jobs: Vec<BatchJob> = (0..6).map(|i| job(i, 8, 1.0)).collect();
        // user 0 owns jobs 0..4, user 1 owns jobs 4..6.
        for j in jobs.iter_mut().take(4) {
            j.user = 0;
        }
        for j in jobs.iter_mut().skip(4) {
            j.user = 1;
        }
        let r = BatchQueueSim::new(QueuePolicy::Fairshare)
            .run(&jobs, &cluster(8))
            .unwrap();
        // User 1's first job should run 2nd (after user 0 accumulates usage).
        assert!(
            r.outcomes[4].start <= 1.0 + 1e-9,
            "user 1 starved: starts at {}",
            r.outcomes[4].start
        );
    }

    #[test]
    fn arrivals_respected() {
        let mut jobs = vec![job(0, 4, 2.0), job(1, 4, 2.0)];
        jobs[1].submit_at = 10.0;
        let r = BatchQueueSim::new(QueuePolicy::Fcfs)
            .run(&jobs, &cluster(8))
            .unwrap();
        assert_eq!(r.outcomes[1].start, 10.0);
        assert_eq!(r.makespan, 12.0);
    }

    #[test]
    fn rejects_oversized_jobs() {
        let jobs = vec![job(0, 16, 1.0)];
        assert!(BatchQueueSim::new(QueuePolicy::Fcfs)
            .run(&jobs, &cluster(8))
            .is_err());
    }

    #[test]
    fn scratch_reuse_matches_fresh() {
        let jobs = vec![job(0, 4, 10.0), job(1, 8, 10.0), job(2, 4, 5.0)];
        let mut scratch = SimScratch::new();
        for policy in [QueuePolicy::Fcfs, QueuePolicy::FcfsBackfill, QueuePolicy::Priority] {
            let sim = BatchQueueSim::new(policy);
            let warm = sim
                .run_with_scratch(&jobs, &cluster(8), &mut scratch)
                .unwrap();
            let fresh = sim.run(&jobs, &cluster(8)).unwrap();
            assert_eq!(warm.makespan.to_bits(), fresh.makespan.to_bits());
            for (a, b) in warm.outcomes.iter().zip(&fresh.outcomes) {
                assert_eq!(a.start.to_bits(), b.start.to_bits());
                assert_eq!(a.end.to_bits(), b.end.to_bits());
            }
        }
    }

    #[test]
    fn utilization_bounds() {
        let jobs: Vec<BatchJob> = (0..32).map(|i| job(i, 1, 4.0)).collect();
        let r = BatchQueueSim::new(QueuePolicy::Fcfs)
            .run(&jobs, &cluster(8))
            .unwrap();
        assert!((r.utilization - 1.0).abs() < 1e-9, "u={}", r.utilization);
        assert_eq!(r.makespan, 16.0);
    }

    #[test]
    fn empty_job_list_is_fine() {
        let r = BatchQueueSim::new(QueuePolicy::Fcfs)
            .run(&[], &cluster(8))
            .unwrap();
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.utilization, 1.0);
        assert!(r.outcomes.is_empty());
    }
}
