//! Mesos-like two-level scheduler simulator.
//!
//! Mechanism (mirrors mesos-master + one framework scheduler):
//!
//! * agents (nodes) publish their free resources to the **allocator**,
//!   which batches them into per-agent resource offers every
//!   `offer_interval` (Mesos 0.25 default allocation interval = 1 s);
//! * the **framework** receives offers, accepts them for pending tasks
//!   (per-offer handling cost at the master), and launches one executor
//!   per task — the executor registration/startup is the dominant
//!   per-task overhead at long task times;
//! * completions transit the master's status-update path before
//!   resources are re-offered.
//!
//! Per-task master cost is mostly flat (offers amortize over batches) ⇒
//! fitted α_s ≈ 1.1 with t_s between Grid Engine and YARN, as the paper
//! measures (Table 10), and lower ΔT than Slurm/GE at high n (Figure 4c).

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::ClusterSpec;
use crate::sim::{ServiceStation, SimEv, SimScratch};
use crate::util::prng::{LognormalGen, Prng};
use crate::util::stats::Summary;
use crate::workload::{TraceRecord, Workload};

/// Mechanism parameters for the Mesos-like model.
#[derive(Clone, Debug)]
pub struct MesosParams {
    /// Display name.
    pub name: &'static str,
    /// Allocator offer cycle (s).
    pub offer_interval: f64,
    /// Master serial cost per offer batch sent to the framework
    /// (covers all agents in the round).
    pub offer_batch_cost: f64,
    /// Master serial cost per task launch (accept + TaskInfo handling).
    pub launch_cost_per_task: f64,
    /// Master serial cost per status update (TASK_FINISHED path).
    pub complete_cost_per_task: f64,
    /// Framework scheduler response latency per offer round (s).
    pub framework_latency: f64,
    /// Executor fetch/registration/startup mean before the task runs (s).
    pub executor_startup_mean: f64,
    /// CV of executor startup.
    pub executor_startup_cv: f64,
    /// Agent housekeeping after a task before resources are re-offerable.
    pub agent_teardown: f64,
    /// One-way RPC latency (s).
    pub rpc: f64,
    /// CV of lognormal jitter on master service times.
    pub jitter_cv: f64,
}

/// Mesos-like simulator.
pub struct MesosSim {
    params: MesosParams,
}

impl MesosSim {
    /// New simulator.
    pub fn new(params: MesosParams) -> Self {
        Self { params }
    }

    /// Access parameters.
    pub fn params(&self) -> &MesosParams {
        &self.params
    }
}

impl Scheduler for MesosSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let p = &self.params;
        let mut rng = Prng::new(seed ^ 0x4E50_05E5);
        // Precomputed jitter distributions (hot path).
        let g_offer = LognormalGen::new(p.offer_batch_cost, p.jitter_cv);
        let g_launch = LognormalGen::new(p.launch_cost_per_task, p.jitter_cv);
        let g_complete = LognormalGen::new(p.complete_cost_per_task, p.jitter_cv);
        let g_exec = LognormalGen::new(p.executor_startup_mean, p.executor_startup_cv);
        let n = workload.len();
        scratch.begin(cluster, n, options.collect_trace);
        let SimScratch {
            queue: q,
            pending,
            pool,
            slot_mem,
            trace,
            trace_idx,
            ..
        } = scratch;
        let mut master = ServiceStation::new();

        for t in &workload.tasks {
            if t.submit_at <= 0.0 && !options.individual_submission {
                pending.push_back(t.id);
            } else {
                q.push(t.submit_at.max(0.0), SimEv::Arrive { task: t.id });
            }
        }
        let mut makespan: f64 = 0.0;
        let mut completed = 0usize;
        let mut waits = Summary::new();

        // Framework registration; first offer round follows.
        q.push(p.framework_latency, SimEv::Tick);

        while let Some((now, ev)) = q.pop() {
            match ev {
                SimEv::Arrive { task } => {
                    master.serve(now, rng.lognormal(&g_launch));
                    pending.push_back(task);
                }
                SimEv::Tick => {
                    if pool.free_count() > 0 && !pending.is_empty() {
                        // One offer batch covering all currently-free agents.
                        let t_off = master.serve(now, rng.lognormal(&g_offer));
                        let respond_at = t_off + p.rpc + p.framework_latency;
                        // Framework accepts: one launch per pending task that
                        // fits the offered resources.
                        while !pending.is_empty() {
                            let task_id = *pending.front().unwrap();
                            let task = &workload.tasks[task_id as usize];
                            let Some(slot) = pool.alloc(task.mem_mb) else {
                                break;
                            };
                            pending.pop_front();
                            slot_mem[slot as usize] = task.mem_mb;
                            let fin = master.serve(respond_at, rng.lognormal(&g_launch));
                            let exec = rng.lognormal(&g_exec);
                            q.push(fin + p.rpc + exec, SimEv::Start { task: task_id, slot });
                        }
                    }
                    if completed < n {
                        q.push(now + p.offer_interval, SimEv::Tick);
                    }
                }
                SimEv::Start { task, slot } => {
                    let spec = &workload.tasks[task as usize];
                    waits.add(now - spec.submit_at);
                    if options.collect_trace {
                        trace_idx[task as usize] = trace.len() as u32;
                        trace.push(TraceRecord {
                            task,
                            node: pool.node_of(slot),
                            slot,
                            submit: spec.submit_at,
                            start: now,
                            end: 0.0,
                        });
                    }
                    q.push(now + spec.duration, SimEv::End { task, slot });
                }
                SimEv::End { task, slot } => {
                    completed += 1;
                    makespan = makespan.max(now);
                    if options.collect_trace {
                        trace[trace_idx[task as usize] as usize].end = now;
                    }
                    let fin = master.serve(now, rng.lognormal(&g_complete));
                    q.push(fin + p.agent_teardown, SimEv::SlotFree { slot });
                }
                SimEv::SlotFree { slot } => {
                    pool.release(slot, slot_mem[slot as usize]);
                }
                SimEv::Stage { .. } => unreachable!("mesos sim emits no Stage events"),
            }
        }

        debug_assert_eq!(completed, n);
        let processors = cluster.total_cores();
        let events = q.popped();
        RunResult {
            scheduler: p.name.to_string(),
            workload: workload.label.clone(),
            n_tasks: n as u64,
            processors,
            t_total: makespan,
            t_job: workload.t_job_per_proc(processors),
            events,
            daemon_busy: master.busy(),
            waits,
            trace: options.collect_trace.then(|| std::mem::take(trace)),
        }
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        let p = cluster.total_cores() as f64;
        let per_task =
            self.params.launch_cost_per_task + self.params.complete_cost_per_task;
        (workload.total_work() / p).max(workload.len() as f64 * per_task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::calibration;
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
    }

    #[test]
    fn completes_and_valid() {
        let sim = MesosSim::new(calibration::mesos_params());
        let w = WorkloadBuilder::constant(2.0).tasks(64).label("m").build();
        let r = sim.run(&w, &cluster(), 3, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.n_tasks, 64);
    }

    #[test]
    fn deterministic() {
        let sim = MesosSim::new(calibration::mesos_params());
        let w = WorkloadBuilder::constant(1.0).tasks(50).build();
        let a = sim.run(&w, &cluster(), 9, &RunOptions::default());
        let b = sim.run(&w, &cluster(), 9, &RunOptions::default());
        assert_eq!(a.t_total, b.t_total);
    }

    #[test]
    fn offer_cycle_delays_execution() {
        // With few long tasks, per-task overhead ≈ offer wait + executor
        // startup: ΔT must be positive but small relative to work.
        let sim = MesosSim::new(calibration::mesos_params());
        let w = WorkloadBuilder::constant(60.0).tasks(16).label("l").build();
        let r = sim.run(&w, &cluster(), 5, &RunOptions::default());
        assert!(r.delta_t() > 0.0);
        assert!(r.utilization() > 0.8, "u={}", r.utilization());
    }
}
