//! Mesos-like two-level scheduler policy.
//!
//! Mechanism (mirrors mesos-master + one framework scheduler):
//!
//! * agents (nodes) publish their free resources to the **allocator**,
//!   which batches them into per-agent resource offers every
//!   `offer_interval` (Mesos 0.25 default allocation interval = 1 s);
//! * the **framework** receives offers, accepts them for pending tasks
//!   (per-offer handling cost at the master), and launches one executor
//!   per task — the executor registration/startup is the dominant
//!   per-task overhead at long task times;
//! * completions transit the master's status-update path before
//!   resources are re-offered.
//!
//! Per-task master cost is mostly flat (offers amortize over batches) ⇒
//! fitted α_s ≈ 1.1 with t_s between Grid Engine and YARN, as the paper
//! measures (Table 10), and lower ΔT than Slurm/GE at high n (Figure 4c).
//!
//! The event loop lives in [`crate::sim::Kernel`]; this file only
//! prices offer rounds, launches and status updates.

use super::result::{RunOptions, RunResult};
use super::Scheduler;
use crate::cluster::{ClusterSpec, NodeId};
use crate::sim::{Kernel, KernelCtx, Launch, SchedPolicy, ServiceStation, SimEv, SimScratch, Time};
use crate::util::prng::{LognormalGen, Prng};
use crate::workload::{TaskId, Workload};

/// Mechanism parameters for the Mesos-like model.
#[derive(Clone, Debug)]
pub struct MesosParams {
    /// Display name.
    pub name: &'static str,
    /// Allocator offer cycle (s).
    pub offer_interval: f64,
    /// Master serial cost per offer batch sent to the framework
    /// (covers all agents in the round).
    pub offer_batch_cost: f64,
    /// Master serial cost per task launch (accept + TaskInfo handling).
    pub launch_cost_per_task: f64,
    /// Master serial cost per status update (TASK_FINISHED path).
    pub complete_cost_per_task: f64,
    /// Framework scheduler response latency per offer round (s).
    pub framework_latency: f64,
    /// Executor fetch/registration/startup mean before the task runs (s).
    pub executor_startup_mean: f64,
    /// CV of executor startup.
    pub executor_startup_cv: f64,
    /// Agent housekeeping after a task before resources are re-offerable.
    pub agent_teardown: f64,
    /// One-way RPC latency (s).
    pub rpc: f64,
    /// CV of lognormal jitter on master service times.
    pub jitter_cv: f64,
}

/// Mesos-like simulator.
pub struct MesosSim {
    params: MesosParams,
}

impl MesosSim {
    /// New simulator.
    pub fn new(params: MesosParams) -> Self {
        Self { params }
    }

    /// Access parameters.
    pub fn params(&self) -> &MesosParams {
        &self.params
    }
}

/// Per-run policy state: the master station + jitter distributions.
struct MesosPolicy<'p> {
    p: &'p MesosParams,
    rng: Prng,
    g_offer: LognormalGen,
    g_launch: LognormalGen,
    g_complete: LognormalGen,
    g_exec: LognormalGen,
    master: ServiceStation,
}

impl SchedPolicy for MesosPolicy<'_> {
    fn label(&self) -> String {
        self.p.name.to_string()
    }

    fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
        // Framework registration; first offer round follows.
        ctx.push(self.p.framework_latency, SimEv::Tick);
    }

    fn on_arrive(&mut self, _ctx: &mut KernelCtx, now: Time, _task: TaskId) {
        self.master.serve(now, self.rng.lognormal(&self.g_launch));
    }

    fn tick_interval(&self) -> Option<Time> {
        Some(self.p.offer_interval)
    }

    fn on_tick(&mut self, ctx: &mut KernelCtx, now: Time) {
        if ctx.free_slots() > 0 && ctx.pending_len() > 0 {
            // One offer batch covering all currently-free agents.
            let t_off = self.master.serve(now, self.rng.lognormal(&self.g_offer));
            let respond_at = t_off + self.p.rpc + self.p.framework_latency;
            // Framework accepts: one launch per pending task that fits
            // the offered resources.
            let (master, rng) = (&mut self.master, &mut self.rng);
            let (g_launch, g_exec, rpc) = (&self.g_launch, &self.g_exec, self.p.rpc);
            ctx.drain_fifo(&mut |_, _| {
                let fin = master.serve(respond_at, rng.lognormal(g_launch));
                let exec = rng.lognormal(g_exec);
                Launch::start(fin + rpc + exec)
            });
        }
    }

    fn on_complete(
        &mut self,
        _ctx: &mut KernelCtx,
        now: Time,
        _task: TaskId,
        _slot: u32,
    ) -> Option<Time> {
        let fin = self.master.serve(now, self.rng.lognormal(&self.g_complete));
        Some(fin + self.p.agent_teardown)
    }

    // Node faults are deliberate no-ops: offers are regenerated from
    // the live free-slot pool every `offer_interval`, so a dead
    // agent's resources never appear in the next offer batch — the
    // master has effectively rescinded them — and the kernel requeues
    // its killed tasks for the framework to accept against a later
    // round. Recovery is just the agent re-registering: its slots are
    // back in the next offer.
    fn on_node_fail(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn on_node_suspected(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {
        // Same as on_node_fail: the next offer round is built from the
        // live pool, which the (late) detection just shrank.
    }

    fn on_node_drain(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn on_node_recover(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    fn daemon_busy(&self) -> f64 {
        self.master.busy()
    }
}

impl Scheduler for MesosSim {
    fn name(&self) -> &'static str {
        self.params.name
    }

    fn make_policy<'a>(&'a self, seed: u64) -> Option<Box<dyn SchedPolicy + 'a>> {
        let p = &self.params;
        Some(Box::new(MesosPolicy {
            p,
            rng: Prng::new(seed ^ 0x4E50_05E5),
            g_offer: LognormalGen::new(p.offer_batch_cost, p.jitter_cv),
            g_launch: LognormalGen::new(p.launch_cost_per_task, p.jitter_cv),
            g_complete: LognormalGen::new(p.complete_cost_per_task, p.jitter_cv),
            g_exec: LognormalGen::new(p.executor_startup_mean, p.executor_startup_cv),
            master: ServiceStation::new(),
        }))
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let mut policy = self.make_policy(seed).expect("mesos is kernel-driven");
        Kernel::run(policy.as_mut(), workload, cluster, options, scratch)
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        let p = cluster.total_cores() as f64;
        let per_task = self.params.launch_cost_per_task + self.params.complete_cost_per_task;
        (workload.total_work() / p).max(workload.len() as f64 * per_task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::calibration;
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
    }

    #[test]
    fn completes_and_valid() {
        let sim = MesosSim::new(calibration::mesos_params());
        let w = WorkloadBuilder::constant(2.0).tasks(64).label("m").build();
        let r = sim.run(&w, &cluster(), 3, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        assert_eq!(r.n_tasks, 64);
    }

    #[test]
    fn deterministic() {
        let sim = MesosSim::new(calibration::mesos_params());
        let w = WorkloadBuilder::constant(1.0).tasks(50).build();
        let a = sim.run(&w, &cluster(), 9, &RunOptions::default());
        let b = sim.run(&w, &cluster(), 9, &RunOptions::default());
        assert_eq!(a.t_total, b.t_total);
    }

    #[test]
    fn offer_cycle_delays_execution() {
        // With few long tasks, per-task overhead ≈ offer wait + executor
        // startup: ΔT must be positive but small relative to work.
        let sim = MesosSim::new(calibration::mesos_params());
        let w = WorkloadBuilder::constant(60.0).tasks(16).label("l").build();
        let r = sim.run(&w, &cluster(), 5, &RunOptions::default());
        assert!(r.delta_t() > 0.0);
        assert!(r.utilization() > 0.8, "u={}", r.utilization());
    }

    #[test]
    fn gang_jobs_start_together_through_offers() {
        let sim = MesosSim::new(calibration::mesos_params());
        let w = WorkloadBuilder::constant(10.0)
            .tasks(32)
            .gangs(4)
            .label("g")
            .build();
        let r = sim.run(&w, &cluster(), 6, &RunOptions::with_trace());
        r.check_invariants().unwrap();
        // Members of each gang must be dispatched in the same offer
        // round: their starts differ only by per-task launch costs,
        // far below the 1 s offer interval.
        let trace = r.trace.as_ref().unwrap();
        for job in 0..8u32 {
            let starts: Vec<f64> = trace
                .iter()
                .filter(|t| w.tasks[t.task as usize].job == job)
                .map(|t| t.start)
                .collect();
            assert_eq!(starts.len(), 4);
            let lo = starts.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = starts.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(hi - lo < 5.0, "gang {job} start skew {}", hi - lo);
        }
    }
}
