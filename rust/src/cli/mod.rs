//! Hand-rolled CLI argument parsing (clap is not in the offline crate
//! set). Flags are `--name value` or `--name` (boolean); the first
//! non-flag token is the subcommand.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Subcommand (first positional).
    pub command: Option<String>,
    /// Remaining positionals.
    pub positionals: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// `--flag` booleans.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty flag `--`".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Boolean flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("invalid value `{v}` for --{name}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_flags_options() {
        let a = parse("experiment table9 --trials 2 --quick --out-dir out");
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positionals, vec!["table9"]);
        assert_eq!(a.opt("trials"), Some("2"));
        assert!(a.flag("quick"));
        assert_eq!(a.opt("out-dir"), Some("out"));
    }

    #[test]
    fn equals_form() {
        let a = parse("features --table=3");
        assert_eq!(a.opt("table"), Some("3"));
    }

    #[test]
    fn typed_options() {
        let a = parse("x --n 7");
        assert_eq!(a.opt_parse("n", 1u32).unwrap(), 7);
        assert_eq!(a.opt_parse("missing", 42u32).unwrap(), 42);
        assert!(parse("x --n seven").opt_parse("n", 1u32).is_err());
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("cmd --quick --n 3");
        assert!(a.flag("quick"));
        assert_eq!(a.opt("n"), Some("3"));
    }
}
