//! Hand-rolled CLI argument parsing (clap is not in the offline crate
//! set). Flags are `--name value` or `--name` (boolean); the first
//! non-flag token is the subcommand.
//!
//! Boolean flags are declared in [`BOOL_FLAGS`]: a known-boolean flag
//! never consumes the following token as its value, so
//! `sssched experiment --quick fig4` parses `fig4` as the positional it
//! is instead of as the value of `--quick` (the historical bug this
//! set fixes). Unknown `--flag token` pairs still bind greedily, which
//! keeps forward compatibility for new valued options.

use std::collections::BTreeMap;

/// Flags the CLI treats as boolean: they never take a value.
pub const BOOL_FLAGS: &[&str] = &["quick", "csv", "full", "huge", "churn"];

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Subcommand (first positional).
    pub command: Option<String>,
    /// Remaining positionals.
    pub positionals: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// `--flag` booleans.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding `argv[0]`), with the
    /// default [`BOOL_FLAGS`] set.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        Self::parse_with_bools(args, BOOL_FLAGS)
    }

    /// Parse with an explicit set of known-boolean flag names.
    pub fn parse_with_bools<I: IntoIterator<Item = String>>(
        args: I,
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty flag `--`".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().expect("peeked value exists");
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Boolean flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("invalid value `{v}` for --{name}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_flags_options() {
        let a = parse("experiment table9 --trials 2 --quick --out-dir out");
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positionals, vec!["table9"]);
        assert_eq!(a.opt("trials"), Some("2"));
        assert!(a.flag("quick"));
        assert_eq!(a.opt("out-dir"), Some("out"));
    }

    #[test]
    fn equals_form() {
        let a = parse("features --table=3");
        assert_eq!(a.opt("table"), Some("3"));
    }

    #[test]
    fn typed_options() {
        let a = parse("x --n 7");
        assert_eq!(a.opt_parse("n", 1u32).unwrap(), 7);
        assert_eq!(a.opt_parse("missing", 42u32).unwrap(), 42);
        assert!(parse("x --n seven").opt_parse("n", 1u32).is_err());
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("cmd --quick --n 3");
        assert!(a.flag("quick"));
        assert_eq!(a.opt("n"), Some("3"));
    }

    #[test]
    fn churn_is_boolean_and_keeps_positionals() {
        // `--churn model` must parse `model` as the experiment name,
        // not as the flag's value.
        let a = parse("experiment --churn model --jobs 2");
        assert!(a.flag("churn"));
        assert_eq!(a.positionals, vec!["model"]);
        assert_eq!(a.opt("jobs"), Some("2"));
    }

    #[test]
    fn boolean_flag_does_not_swallow_positional() {
        // Regression: `--quick fig4` used to parse as quick=fig4,
        // losing the positional entirely.
        let a = parse("experiment --quick fig4");
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert!(a.flag("quick"));
        assert_eq!(a.positionals, vec!["fig4"]);
        assert_eq!(a.opt("quick"), None);

        // Even as the first token, the subcommand survives.
        let a = parse("--quick validate");
        assert!(a.flag("quick"));
        assert_eq!(a.command.as_deref(), Some("validate"));
    }

    #[test]
    fn boolean_flag_equals_form_still_binds() {
        let a = parse("cmd --quick=yes run");
        assert_eq!(a.opt("quick"), Some("yes"));
        assert_eq!(a.positionals, vec!["run"]);
    }

    #[test]
    fn unknown_flags_still_bind_values() {
        let a = parse("cmd --workers 4 next");
        assert_eq!(a.opt("workers"), Some("4"));
        assert_eq!(a.positionals, vec!["next"]);
    }

    #[test]
    fn custom_bool_set() {
        let a = Args::parse_with_bools(
            "cmd --verbose run".split_whitespace().map(String::from),
            &["verbose"],
        )
        .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["run"]);
    }
}
