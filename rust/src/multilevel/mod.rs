//! Multilevel scheduling — the paper's §5.3 (LLMapReduce, Byun et al.
//! HPEC 2016).
//!
//! Instead of submitting N short tasks through the scheduler, the
//! aggregator rewrites the job as P mapper jobs, one per processor,
//! each processing n = N/P input files inside a single scheduler-level
//! task. The scheduler then only pays its per-task overhead P times
//! instead of N times, which is what lifts utilization for 1–5 s tasks
//! from <10 % to >90 % (Figures 6–7).
//!
//! Two modes, as in the paper:
//! * **mimo** (multiple-input multiple-output): the map application
//!   starts once and iterates over its input list — per-input cost is a
//!   small file-handling overhead;
//! * **siso** (single-input single-output): the map application restarts
//!   per input pair — per-input cost includes the application startup,
//!   "overhead associated with repeated startups of the map application".

use crate::cluster::ClusterSpec;
use crate::sched::{RunOptions, RunResult, Scheduler};
use crate::util::prng::Prng;
use crate::workload::{TaskSpec, Workload};

/// Aggregation mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapMode {
    /// Map application starts once per bundle and streams input pairs.
    Mimo,
    /// Map application restarts for every input pair.
    Siso,
}

/// LLMapReduce-style aggregation parameters.
#[derive(Clone, Debug)]
pub struct MultilevelParams {
    /// Aggregation mode.
    pub mode: MapMode,
    /// Mapper job startup (interpreter launch, input-list read) (s).
    pub mapper_startup: f64,
    /// Per-input-pair handling overhead in mimo mode (s).
    pub per_input_overhead: f64,
    /// Application startup paid per input in siso mode (s).
    pub app_startup: f64,
    /// CV of lognormal jitter on the overheads.
    pub jitter_cv: f64,
}

impl Default for MultilevelParams {
    fn default() -> Self {
        Self {
            mode: MapMode::Mimo,
            mapper_startup: 1.0,
            per_input_overhead: 0.020,
            app_startup: 0.75,
            jitter_cv: 0.25,
        }
    }
}

/// The multilevel scheduler: wraps an inner scheduler, aggregating the
/// workload before submission.
pub struct Multilevel<'a> {
    inner: &'a dyn Scheduler,
    params: MultilevelParams,
    /// Mapper bundles per processor used by the `run*` path. The
    /// paper's default is 1 (one mapper per processor); the `model`
    /// experiment's auto-tuner derives larger values when the fitted
    /// (t_s, α_s) predicts the target utilization is still met.
    bundles_per_proc: u64,
}

impl<'a> Multilevel<'a> {
    /// Wrap `inner` with aggregation parameters and the paper's default
    /// of one mapper bundle per processor.
    pub fn new(inner: &'a dyn Scheduler, params: MultilevelParams) -> Self {
        Self::with_bundles_per_proc(inner, params, 1)
    }

    /// Wrap `inner`, aggregating to `bundles_per_proc` mapper bundles
    /// per processor instead of the default one. Keeping the bundle
    /// count an integer multiple of P avoids wave quantization: every
    /// processor runs exactly `bundles_per_proc` equal-shape bundles.
    pub fn with_bundles_per_proc(
        inner: &'a dyn Scheduler,
        params: MultilevelParams,
        bundles_per_proc: u64,
    ) -> Self {
        assert!(bundles_per_proc > 0);
        Self {
            inner,
            params,
            bundles_per_proc,
        }
    }

    /// Rewrite an N-task workload into `bundles` mapper jobs.
    ///
    /// Tasks are dealt round-robin so variable-duration workloads stay
    /// balanced (LLMapReduce splits the input file list the same way).
    pub fn aggregate(&self, workload: &Workload, bundles: u64, seed: u64) -> Workload {
        assert!(bundles > 0);
        // Folding a service into a finite mapper bundle would silently
        // run it as batch work — the exact failure mode the kernel's
        // horizon guard exists to prevent. Refuse loudly instead.
        assert!(
            !workload.has_services(),
            "multilevel aggregation cannot express JobKind::Service tasks; \
             run services directly on a backend with RunOptions::horizon"
        );
        let mut rng = Prng::new(seed ^ 0x11A9_0D0C);
        let p = &self.params;
        let mut durations = vec![0.0f64; bundles as usize];
        let mut counts = vec![0u64; bundles as usize];
        for (i, t) in workload.tasks.iter().enumerate() {
            let b = i % bundles as usize;
            durations[b] += t.duration;
            counts[b] += 1;
        }
        let tasks = durations
            .iter()
            .zip(&counts)
            .enumerate()
            .filter(|(_, (_, &c))| c > 0)
            .map(|(i, (&work, &c))| {
                let overhead = match p.mode {
                    MapMode::Mimo => {
                        rng.lognormal_mean_cv(p.mapper_startup, p.jitter_cv)
                            + c as f64 * rng.lognormal_mean_cv(p.per_input_overhead, p.jitter_cv)
                    }
                    MapMode::Siso => {
                        rng.lognormal_mean_cv(p.mapper_startup, p.jitter_cv)
                            + c as f64 * rng.lognormal_mean_cv(p.app_startup, p.jitter_cv)
                    }
                };
                let mut t = TaskSpec::array(i as u32, 0, work + overhead);
                t.mem_mb = workload.tasks.first().map(|t| t.mem_mb).unwrap_or(2048);
                t
            })
            .collect();
        Workload {
            tasks,
            label: format!("{}+ml", workload.label),
        }
    }
}

impl<'a> Scheduler for Multilevel<'a> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn make_policy<'b>(&'b self, _seed: u64) -> Option<Box<dyn crate::sim::SchedPolicy + 'b>> {
        // Multilevel is a workload transformation around an inner
        // scheduler, not a single kernel policy: the preemption /
        // ordering combinators cannot wrap it directly (wrap the inner
        // backend instead).
        None
    }

    fn run_with_scratch(
        &self,
        workload: &Workload,
        cluster: &ClusterSpec,
        seed: u64,
        options: &RunOptions,
        scratch: &mut crate::sim::SimScratch,
    ) -> RunResult {
        let processors = cluster.total_cores();
        // The aggregated workload is P tasks — small next to the N-task
        // input — so building it per run is off the zero-alloc critical
        // path; the inner simulation reuses the scratch.
        //
        // Fault plans pass straight through to the inner backend's
        // kernel run: a node failure kills the mapper bundles running
        // there and the inner scheduler retries each whole bundle
        // elsewhere under `TaskSpec::max_retries`. Aggregation widens
        // the blast radius — one kill loses the bundle's entire
        // accumulated work, the price of hiding N tasks inside P — but
        // no bundle is ever stranded on a dead node.
        let aggregated = self.aggregate(workload, processors * self.bundles_per_proc, seed);
        let mut result = self
            .inner
            .run_with_scratch(&aggregated, cluster, seed, options, scratch);
        // ΔT and U are defined against the ORIGINAL workload's isolated
        // job time — the mapper overheads count as scheduler-path
        // overhead, exactly as in the paper's Figure 6/7 accounting.
        result.t_job = workload.t_job_per_proc(processors);
        result.scheduler = format!("{}+multilevel", self.inner.name());
        result.workload = workload.label.clone();
        result
    }

    fn projected_runtime(&self, workload: &Workload, cluster: &ClusterSpec) -> f64 {
        // `bundles_per_proc` mappers per processor: the scheduler only
        // sees m·P tasks, and each processor pays m mapper startups.
        workload.total_work() / cluster.total_cores() as f64
            + self.params.mapper_startup * self.bundles_per_proc as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{calibration, centralized::CentralizedSim};
    use crate::workload::WorkloadBuilder;

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 8, 32 * 1024, 2)
    }

    #[test]
    fn aggregation_conserves_work() {
        let inner = CentralizedSim::new(calibration::slurm_params());
        let ml = Multilevel::new(&inner, MultilevelParams::default());
        let w = WorkloadBuilder::constant(1.0).tasks(160).build();
        let agg = ml.aggregate(&w, 16, 0);
        assert_eq!(agg.len(), 16);
        // Aggregate work >= original (overheads added, none lost).
        assert!(agg.total_work() >= w.total_work());
        // Each bundle carries 10 tasks of 1 s + ~1 s startup + small per-input.
        for t in &agg.tasks {
            assert!(t.duration > 10.0 && t.duration < 14.0, "dur={}", t.duration);
        }
    }

    #[test]
    #[should_panic(expected = "Service")]
    fn aggregation_refuses_service_tasks() {
        let inner = CentralizedSim::new(calibration::slurm_params());
        let ml = Multilevel::new(&inner, MultilevelParams::default());
        let mut w = WorkloadBuilder::constant(1.0).tasks(4).build();
        w.tasks[0].kind = crate::workload::JobKind::Service;
        ml.aggregate(&w, 2, 0);
    }

    #[test]
    fn siso_overhead_exceeds_mimo() {
        let inner = CentralizedSim::new(calibration::slurm_params());
        let mimo = Multilevel::new(&inner, MultilevelParams::default());
        let siso = Multilevel::new(
            &inner,
            MultilevelParams {
                mode: MapMode::Siso,
                ..MultilevelParams::default()
            },
        );
        let w = WorkloadBuilder::constant(1.0).tasks(160).build();
        assert!(
            siso.aggregate(&w, 16, 0).total_work() > mimo.aggregate(&w, 16, 0).total_work()
        );
    }

    #[test]
    fn multilevel_improves_short_task_utilization() {
        let inner = CentralizedSim::new(calibration::slurm_params());
        let w = WorkloadBuilder::constant(1.0).tasks(16 * 100).label("r").build();
        let base = inner.run(&w, &cluster(), 3, &RunOptions::default());
        let ml = Multilevel::new(&inner, MultilevelParams::default());
        let improved = ml.run(&w, &cluster(), 3, &RunOptions::default());
        assert!(
            improved.utilization() > base.utilization() * 1.5,
            "ml={} base={}",
            improved.utilization(),
            base.utilization()
        );
        improved.check_invariants().unwrap();
        // Same isolated job time accounting.
        assert!((improved.t_job - base.t_job).abs() < 1e-9);
    }

    #[test]
    fn node_failure_retries_whole_bundles() {
        use crate::cluster::FaultPlan;
        // 16 bundles of ~11 s fill all 16 slots; node 0 dies at t=5,
        // killing the 8 bundles running there. Each retries elsewhere
        // from zero (aggregation loses the whole bundle's work).
        let inner = CentralizedSim::new(calibration::slurm_params());
        let ml = Multilevel::new(&inner, MultilevelParams::default());
        let w = WorkloadBuilder::constant(1.0).tasks(160).label("mlf").build();
        let mut options = RunOptions::default();
        options.faults = FaultPlan::none().fail(5.0, 0);
        let r = ml.run(&w, &cluster(), 3, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 8, "one bundle per slot on the dead node");
        assert_eq!(r.failed, 0, "retry budget absorbs one kill");
        assert!(r.wasted_core_seconds > 8.0 * 3.0, "each lost ~5 s minus dispatch");
        let baseline = ml.run(&w, &cluster(), 3, &RunOptions::default());
        assert!(r.t_total > baseline.t_total, "retries on half capacity cost time");
    }

    #[test]
    fn bundles_per_proc_override_changes_bundle_count() {
        let inner = CentralizedSim::new(calibration::slurm_params());
        let w = WorkloadBuilder::constant(1.0).tasks(16 * 120).label("bpp").build();
        // Default path (m = 1) and the explicit m = 1 form are the same
        // scheduler; m = 3 runs 3× the bundles, so more per-bundle
        // overhead and lower utilization, but still well above the raw
        // backend for 1 s tasks.
        let one = Multilevel::new(&inner, MultilevelParams::default());
        let one_explicit =
            Multilevel::with_bundles_per_proc(&inner, MultilevelParams::default(), 1);
        let three = Multilevel::with_bundles_per_proc(&inner, MultilevelParams::default(), 3);
        assert_eq!(three.aggregate(&w, 3 * 16, 7).len(), 48);
        let r1 = one.run(&w, &cluster(), 9, &RunOptions::default());
        let r1x = one_explicit.run(&w, &cluster(), 9, &RunOptions::default());
        let r3 = three.run(&w, &cluster(), 9, &RunOptions::default());
        r3.check_invariants().unwrap();
        assert_eq!(r1.t_total.to_bits(), r1x.t_total.to_bits());
        assert!(r3.utilization() < r1.utilization());
        assert!((r3.t_job - r1.t_job).abs() < 1e-9, "same isolated job time");
    }

    #[test]
    fn fewer_bundles_than_tasks_ok() {
        let inner = CentralizedSim::new(calibration::slurm_params());
        let ml = Multilevel::new(&inner, MultilevelParams::default());
        // N < P: bundles with zero tasks are dropped.
        let w = WorkloadBuilder::constant(1.0).tasks(5).build();
        let agg = ml.aggregate(&w, 16, 0);
        assert_eq!(agg.len(), 5);
    }
}
