//! Native implementations of the four AOT kernels.
//!
//! Each function mirrors the corresponding Pallas kernel's math (see
//! `python/compile/kernels/`): same inputs, same reductions, f64
//! accumulation (the HLO kernels ran in f32; callers' tolerances cover
//! both). Validation/padding lives in [`super::artifacts`]; these are
//! the raw compute bodies.

use crate::model::{u_constant_approx, u_constant_exact, u_variable};
use crate::util::fit::{fit_power_law, PowerLawFit};
use crate::workload::TABLE9_JOB_TIME_PER_PROC;

/// Masked log-log OLS power-law fit over one series of positive
/// (n, ΔT) points (`powerlaw_fit.hlo.txt` equivalent).
pub fn powerlaw_fit_series(points: &[(f64, f64)]) -> PowerLawFit {
    let ns: Vec<f64> = points.iter().map(|p| p.0).collect();
    let dts: Vec<f64> = points.iter().map(|p| p.1).collect();
    fit_power_law(&ns, &dts)
}

/// Approximate + exact utilization curves for one (t_s, α_s) fit over a
/// task-time grid (`utilization.hlo.txt` equivalent). n is derived from
/// the paper's fixed per-processor work T_job = 240 s.
pub fn utilization_curves_series(t_s: f64, alpha_s: f64, t_grid: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let approx = t_grid.iter().map(|&t| u_constant_approx(t_s, t)).collect();
    let exact = t_grid
        .iter()
        .map(|&t| {
            let n = TABLE9_JOB_TIME_PER_PROC / t;
            u_constant_exact(t_s, alpha_s, t, n)
        })
        .collect();
    (approx, exact)
}

/// Analytics map-task payload (`analytics.hlo.txt` equivalent):
/// features = Σ_b relu(x · w), checksum = Σ_f features.
/// `x` is row-major (b, d), `w` row-major (d, f).
pub fn analytics_payload(x: &[f32], w: &[f32], b: usize, d: usize, f: usize) -> (Vec<f32>, f32) {
    debug_assert_eq!(x.len(), b * d);
    debug_assert_eq!(w.len(), d * f);
    let mut features = vec![0f64; f];
    for bi in 0..b {
        let row = &x[bi * d..(bi + 1) * d];
        for fi in 0..f {
            let mut acc = 0f64;
            for (di, &xv) in row.iter().enumerate() {
                acc += xv as f64 * w[di * f + fi] as f64;
            }
            features[fi] += acc.max(0.0);
        }
    }
    let checksum: f64 = features.iter().sum();
    (
        features.into_iter().map(|v| v as f32).collect(),
        checksum as f32,
    )
}

/// Variable-task-time utilization reduction (`uvar.hlo.txt`
/// equivalent).
pub fn uvar_reduce(per_proc_mean_t: &[f64], t_s: f64) -> f64 {
    u_variable(t_s, per_proc_mean_t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn powerlaw_recovers_synthetic() {
        let pts: Vec<(f64, f64)> = [4.0f64, 8.0, 48.0, 240.0]
            .iter()
            .map(|&n| (n, 2.2 * n.powf(1.3)))
            .collect();
        let fit = powerlaw_fit_series(&pts);
        assert!((fit.t_s - 2.2).abs() < 1e-9);
        assert!((fit.alpha_s - 1.3).abs() < 1e-9);
    }

    #[test]
    fn analytics_uniform_inputs() {
        let (b, d, f) = (4, 8, 3);
        let x = vec![1.0f32; b * d];
        let w = vec![0.5f32; d * f];
        let (feats, checksum) = analytics_payload(&x, &w, b, d, f);
        // Each feature: b batches × relu(d × 0.5).
        for &v in &feats {
            assert!((v - (b * d) as f32 * 0.5).abs() < 1e-6);
        }
        assert!((checksum - feats.iter().sum::<f32>()).abs() < 1e-6);
    }

    #[test]
    fn analytics_relu_clamps_negatives() {
        let (b, d, f) = (1, 2, 1);
        let x = vec![1.0f32, 1.0];
        let w = vec![-3.0f32, 1.0];
        let (feats, _) = analytics_payload(&x, &w, b, d, f);
        assert_eq!(feats[0], 0.0);
    }
}
