//! Execution runtime for the AOT-compiled model kernels.
//!
//! The seed executed Pallas-lowered HLO artifacts (`artifacts/*.hlo.txt`,
//! produced by `python/compile/aot.py`) through the `xla` crate's PJRT
//! CPU client — but neither `xla` nor `anyhow` exists in the offline
//! crate set this repo must build against, so the seed did not compile.
//! The suite now ships a **native backend**: the same four kernels
//! (masked log-log OLS power-law fit, utilization curves, the analytics
//! map-task payload, and the U_v reduction), implemented in Rust with
//! identical shape/validation contracts, behind the unchanged
//! [`ArtifactSuite`] API. Callers — fig5, table10, the realtime
//! workers, examples — are source-compatible; reintroducing a PJRT
//! backend later only means adding a second arm behind
//! [`ArtifactSuite`].

mod artifacts;
mod native;

pub use artifacts::{shapes, ArtifactSuite, PjrtFit};

/// Runtime error (string-typed — the offline crate set has no `anyhow`).
#[derive(Clone, Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<String> for RuntimeError {
    fn from(s: String) -> Self {
        Self(s)
    }
}

/// Result alias for runtime operations.
pub type Result<T> = std::result::Result<T, RuntimeError>;
