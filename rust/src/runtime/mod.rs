//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Layer-3 (rust) hot path. Python/JAX is build-time only — see
//! `python/compile/aot.py`. Interchange format is HLO *text* (the image's
//! xla_extension 0.5.1 rejects jax>=0.5 serialized protos).

mod artifacts;
mod pjrt;

pub use artifacts::{shapes, ArtifactSuite, PjrtFit};
pub use pjrt::{Artifact, PjrtRuntime};
