//! Thin wrapper over the `xla` crate (PJRT C API): one CPU client, many
//! compiled executables keyed by artifact name.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled HLO artifact, ready to execute on the PJRT CPU client.
pub struct Artifact {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Name of the artifact (file stem of the `.hlo.txt` it was loaded from).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 buffers, returning the flattened f32 outputs of the
    /// result tuple. All sssched artifacts are lowered with
    /// `return_tuple=True`, so the single output is a tuple of arrays.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let mut result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let tuple = result.decompose_tuple()?;
        tuple
            .into_iter()
            .map(|lit| {
                lit.convert(xla::PrimitiveType::F32)?
                    .to_vec::<f32>()
                    .map_err(Into::into)
            })
            .collect()
    }
}

/// Runtime owning the PJRT client and a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, Artifact>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// Platform name reported by PJRT (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<artifacts_dir>/<name>.hlo.txt`, caching the result.
    pub fn load(&mut self, name: &str) -> Result<&Artifact> {
        if !self.cache.contains_key(name) {
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("loading HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.cache.insert(
                name.to_string(),
                Artifact {
                    name: name.to_string(),
                    exe,
                },
            );
        }
        Ok(&self.cache[name])
    }

    /// Build an f32 literal of the given shape from a flat slice.
    pub fn literal_f32(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(values);
        lit.reshape(dims).map_err(Into::into)
    }
}
