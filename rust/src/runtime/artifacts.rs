//! Typed artifact suite: the four model kernels behind one facade.
//!
//! The shape constants and validation contracts mirror the AOT
//! artifacts' fixed shapes (see `python/compile/model.py`):
//!
//! * `powerlaw_fit`  — (S=8, K=32) masked log-log OLS → (t_s, α, R²)
//! * `utilization`   — (S=8) fits × (T=64) task-time grid → U curves
//! * `analytics`     — (B=256, D=64) × (D, F=32) map-task payload
//! * `uvar`          — (P≤2048) per-processor mean task times → U_v
//!
//! Execution is the native backend in [`super::native`] (the xla/PJRT
//! backend is gated out of the offline build; see the module docs of
//! [`crate::runtime`]).

use super::native;
use super::{Result, RuntimeError};
use std::path::Path;

macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(RuntimeError(format!($($arg)+)));
        }
    };
}

/// Fixed AOT shape constants (mirror python/compile/model.py).
pub mod shapes {
    /// Max fit series per call.
    pub const FIT_S: usize = 8;
    /// Max observations per series.
    pub const FIT_K: usize = 32;
    /// Task-time grid length.
    pub const UTIL_T: usize = 64;
    /// Analytics batch.
    pub const ANALYTICS_B: usize = 256;
    /// Analytics record width.
    pub const ANALYTICS_D: usize = 64;
    /// Analytics feature count.
    pub const ANALYTICS_F: usize = 32;
    /// Padded processor count for the U_v reduction.
    pub const UVAR_P: usize = 2048;
}

/// One power-law fit result from the artifact suite.
#[derive(Clone, Copy, Debug)]
pub struct PjrtFit {
    /// Marginal latency t_s.
    pub t_s: f64,
    /// Nonlinear exponent α_s.
    pub alpha_s: f64,
    /// R² of the log-log fit.
    pub r2: f64,
}

/// Facade exposing the four kernels as typed calls.
pub struct ArtifactSuite {
    platform: &'static str,
}

impl ArtifactSuite {
    /// Open the suite rooted at an artifacts directory. The native
    /// backend needs nothing from disk, so this always succeeds; the
    /// directory is only probed to report honestly whether the AOT HLO
    /// artifacts are present (`platform()`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let have_hlo = ["powerlaw_fit", "utilization", "analytics", "uvar"]
            .iter()
            .all(|name| dir.join(format!("{name}.hlo.txt")).exists());
        Ok(Self {
            platform: if have_hlo {
                "native (hlo artifacts present; xla backend gated out offline)"
            } else {
                "native"
            },
        })
    }

    /// Batched power-law fit: one entry per series of (n, ΔT)
    /// observations. Series longer than K=32 points or batches larger
    /// than S=8 are rejected; non-positive points are masked out, and a
    /// series needs at least 2 positive points.
    pub fn powerlaw_fit(&mut self, series: &[Vec<(f64, f64)>]) -> Result<Vec<PjrtFit>> {
        use shapes::{FIT_K, FIT_S};
        ensure!(
            series.len() <= FIT_S,
            "at most {FIT_S} series per call, got {}",
            series.len()
        );
        let mut out = Vec::with_capacity(series.len());
        for (s, pts) in series.iter().enumerate() {
            let valid: Vec<(f64, f64)> = pts
                .iter()
                .copied()
                .filter(|&(n, dt)| n > 0.0 && dt > 0.0)
                .collect();
            ensure!(
                valid.len() >= 2,
                "series {s} needs >= 2 positive points, has {}",
                valid.len()
            );
            ensure!(
                valid.len() <= FIT_K,
                "series {s} has {} points, max {FIT_K}",
                valid.len()
            );
            let fit = native::powerlaw_fit_series(&valid);
            out.push(PjrtFit {
                t_s: fit.t_s,
                alpha_s: fit.alpha_s,
                r2: fit.r2,
            });
        }
        Ok(out)
    }

    /// Model utilization curves U_c(t) (approx, exact) for up to S=8
    /// fitted schedulers over a T=64 task-time grid.
    pub fn utilization_curves(
        &mut self,
        fits: &[PjrtFit],
        t_grid: &[f64],
    ) -> Result<(Vec<Vec<f64>>, Vec<Vec<f64>>)> {
        use shapes::{FIT_S, UTIL_T};
        ensure!(fits.len() <= FIT_S, "at most {FIT_S} fits per call");
        ensure!(
            t_grid.len() == UTIL_T,
            "t_grid must have exactly {UTIL_T} points, got {}",
            t_grid.len()
        );
        let mut approx = Vec::with_capacity(fits.len());
        let mut exact = Vec::with_capacity(fits.len());
        for f in fits {
            let (a, e) = native::utilization_curves_series(f.t_s, f.alpha_s, t_grid);
            approx.push(a);
            exact.push(e);
        }
        Ok((approx, exact))
    }

    /// Run the analytics map-task payload on one (B, D) record batch.
    /// Returns (features, checksum).
    pub fn analytics(&mut self, x: &[f32], w: &[f32]) -> Result<(Vec<f32>, f32)> {
        use shapes::{ANALYTICS_B, ANALYTICS_D, ANALYTICS_F};
        ensure!(x.len() == ANALYTICS_B * ANALYTICS_D, "x must be B*D");
        ensure!(w.len() == ANALYTICS_D * ANALYTICS_F, "w must be D*F");
        Ok(native::analytics_payload(
            x,
            w,
            ANALYTICS_B,
            ANALYTICS_D,
            ANALYTICS_F,
        ))
    }

    /// Variable-task-time utilization U_v (paper §4 per-processor
    /// averaging): per-processor mean task times (≤ P=2048 entries) +
    /// marginal latency → U.
    pub fn u_variable(&mut self, per_proc_mean_t: &[f64], t_s: f64) -> Result<f64> {
        use shapes::UVAR_P;
        ensure!(
            !per_proc_mean_t.is_empty() && per_proc_mean_t.len() <= UVAR_P,
            "need 1..={UVAR_P} processors, got {}",
            per_proc_mean_t.len()
        );
        ensure!(
            per_proc_mean_t.iter().all(|&t| t > 0.0),
            "per-processor mean task times must be positive"
        );
        Ok(native::uvar_reduce(per_proc_mean_t, t_s))
    }

    /// Backend name.
    pub fn platform(&self) -> String {
        self.platform.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn suite() -> ArtifactSuite {
        ArtifactSuite::load("artifacts").unwrap()
    }

    #[test]
    fn load_succeeds_without_artifacts_dir() {
        let s = ArtifactSuite::load("definitely/not/a/dir").unwrap();
        assert!(s.platform().contains("native"));
    }

    #[test]
    fn powerlaw_validates_shapes() {
        let mut s = suite();
        assert!(s.powerlaw_fit(&[vec![(4.0, 10.0)]]).is_err()); // 1 point
        assert!(s.powerlaw_fit(&[vec![(0.0, 0.0), (-1.0, -5.0)]]).is_err());
        let too_many: Vec<Vec<(f64, f64)>> =
            vec![vec![(4.0, 1.0), (8.0, 2.0)]; shapes::FIT_S + 1];
        assert!(s.powerlaw_fit(&too_many).is_err());
    }

    #[test]
    fn utilization_requires_full_grid() {
        let mut s = suite();
        let fit = PjrtFit {
            t_s: 2.2,
            alpha_s: 1.3,
            r2: 1.0,
        };
        assert!(s.utilization_curves(&[fit], &[1.0, 2.0]).is_err());
        let grid: Vec<f64> = (0..shapes::UTIL_T).map(|i| 1.0 + i as f64).collect();
        let (a, e) = s.utilization_curves(&[fit], &grid).unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(e[0].len(), shapes::UTIL_T);
    }

    #[test]
    fn uvar_validates_inputs() {
        let mut s = suite();
        assert!(s.u_variable(&[], 2.2).is_err());
        assert!(s.u_variable(&[0.0], 2.2).is_err());
        let got = s.u_variable(&[5.0; 100], 2.2).unwrap();
        let want = crate::model::u_constant_approx(2.2, 5.0);
        assert!((got - want).abs() < 1e-12);
    }
}
