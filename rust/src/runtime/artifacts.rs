//! Typed wrappers over the AOT artifacts.
//!
//! Each wrapper owns the padding/unpadding logic for its artifact's
//! fixed AOT shapes (see `python/compile/model.py`):
//!
//! * `powerlaw_fit`  — (S=8, K=32) masked log-log OLS → (t_s, α, R²)
//! * `utilization`   — (S=8) fits × (T=64) task-time grid → U curves
//! * `analytics`     — (B=256, D=64) × (D, F=32) map-task payload

use super::pjrt::PjrtRuntime;
use anyhow::{ensure, Context, Result};

/// Fixed AOT shape constants (mirror python/compile/model.py).
pub mod shapes {
    /// Max fit series per call.
    pub const FIT_S: usize = 8;
    /// Max observations per series.
    pub const FIT_K: usize = 32;
    /// Task-time grid length.
    pub const UTIL_T: usize = 64;
    /// Analytics batch.
    pub const ANALYTICS_B: usize = 256;
    /// Analytics record width.
    pub const ANALYTICS_D: usize = 64;
    /// Analytics feature count.
    pub const ANALYTICS_F: usize = 32;
    /// Padded processor count for the U_v reduction.
    pub const UVAR_P: usize = 2048;
}

/// One power-law fit result from the PJRT path.
#[derive(Clone, Copy, Debug)]
pub struct PjrtFit {
    /// Marginal latency t_s.
    pub t_s: f64,
    /// Nonlinear exponent α_s.
    pub alpha_s: f64,
    /// R² of the log-log fit.
    pub r2: f64,
}

/// Runtime facade exposing the three artifacts as typed calls.
pub struct ArtifactSuite {
    rt: PjrtRuntime,
}

impl ArtifactSuite {
    /// Load the suite from an artifacts directory, compiling all three
    /// HLO artifacts eagerly.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let mut rt = PjrtRuntime::cpu(dir)?;
        for name in ["powerlaw_fit", "utilization", "analytics", "uvar"] {
            rt.load(name)
                .with_context(|| format!("artifact {name} (run `make artifacts`)"))?;
        }
        Ok(Self { rt })
    }

    /// Batched power-law fit through the Pallas kernel: one entry per
    /// series of (n, ΔT) observations. Series longer than K=32 points
    /// or batches larger than S=8 are rejected.
    pub fn powerlaw_fit(&mut self, series: &[Vec<(f64, f64)>]) -> Result<Vec<PjrtFit>> {
        use shapes::{FIT_K, FIT_S};
        ensure!(
            series.len() <= FIT_S,
            "at most {FIT_S} series per call, got {}",
            series.len()
        );
        let mut x = vec![0f32; FIT_S * FIT_K];
        let mut y = vec![0f32; FIT_S * FIT_K];
        let mut m = vec![0f32; FIT_S * FIT_K];
        for (s, pts) in series.iter().enumerate() {
            let valid: Vec<(f64, f64)> = pts
                .iter()
                .copied()
                .filter(|&(n, dt)| n > 0.0 && dt > 0.0)
                .collect();
            ensure!(
                valid.len() >= 2,
                "series {s} needs >= 2 positive points, has {}",
                valid.len()
            );
            ensure!(
                valid.len() <= FIT_K,
                "series {s} has {} points, max {FIT_K}",
                valid.len()
            );
            for (k, &(n, dt)) in valid.iter().enumerate() {
                x[s * FIT_K + k] = (n.ln()) as f32;
                y[s * FIT_K + k] = (dt.ln()) as f32;
                m[s * FIT_K + k] = 1.0;
            }
        }
        let dims = [shapes::FIT_S as i64, FIT_K as i64];
        let inputs = [
            PjrtRuntime::literal_f32(&x, &dims)?,
            PjrtRuntime::literal_f32(&y, &dims)?,
            PjrtRuntime::literal_f32(&m, &dims)?,
        ];
        let out = self.rt.load("powerlaw_fit")?.run_f32(&inputs)?;
        ensure!(out.len() == 3, "powerlaw_fit returns (t_s, alpha, r2)");
        Ok((0..series.len())
            .map(|s| PjrtFit {
                t_s: out[0][s] as f64,
                alpha_s: out[1][s] as f64,
                r2: out[2][s] as f64,
            })
            .collect())
    }

    /// Model utilization curves U_c(t) (approx, exact) for up to S=8
    /// fitted schedulers over a T=64 task-time grid.
    pub fn utilization_curves(
        &mut self,
        fits: &[PjrtFit],
        t_grid: &[f64],
    ) -> Result<(Vec<Vec<f64>>, Vec<Vec<f64>>)> {
        use shapes::{FIT_S, UTIL_T};
        ensure!(fits.len() <= FIT_S, "at most {FIT_S} fits per call");
        ensure!(
            t_grid.len() == UTIL_T,
            "t_grid must have exactly {UTIL_T} points, got {}",
            t_grid.len()
        );
        let mut ts = vec![1.0f32; FIT_S];
        let mut al = vec![1.0f32; FIT_S];
        for (i, f) in fits.iter().enumerate() {
            ts[i] = f.t_s as f32;
            al[i] = f.alpha_s as f32;
        }
        let tg: Vec<f32> = t_grid.iter().map(|&t| t as f32).collect();
        let inputs = [
            PjrtRuntime::literal_f32(&ts, &[FIT_S as i64])?,
            PjrtRuntime::literal_f32(&al, &[FIT_S as i64])?,
            PjrtRuntime::literal_f32(&tg, &[UTIL_T as i64])?,
        ];
        let out = self.rt.load("utilization")?.run_f32(&inputs)?;
        ensure!(out.len() == 2, "utilization returns (approx, exact)");
        let unpack = |flat: &Vec<f32>| -> Vec<Vec<f64>> {
            (0..fits.len())
                .map(|s| {
                    flat[s * UTIL_T..(s + 1) * UTIL_T]
                        .iter()
                        .map(|&v| v as f64)
                        .collect()
                })
                .collect()
        };
        Ok((unpack(&out[0]), unpack(&out[1])))
    }

    /// Run the analytics map-task payload on one (B, D) record batch.
    /// Returns (features, checksum).
    pub fn analytics(&mut self, x: &[f32], w: &[f32]) -> Result<(Vec<f32>, f32)> {
        use shapes::{ANALYTICS_B, ANALYTICS_D, ANALYTICS_F};
        ensure!(x.len() == ANALYTICS_B * ANALYTICS_D, "x must be B*D");
        ensure!(w.len() == ANALYTICS_D * ANALYTICS_F, "w must be D*F");
        let inputs = [
            PjrtRuntime::literal_f32(x, &[ANALYTICS_B as i64, ANALYTICS_D as i64])?,
            PjrtRuntime::literal_f32(w, &[ANALYTICS_D as i64, ANALYTICS_F as i64])?,
        ];
        let out = self.rt.load("analytics")?.run_f32(&inputs)?;
        ensure!(out.len() == 2, "analytics returns (features, checksum)");
        Ok((out[0].clone(), out[1][0]))
    }

    /// Variable-task-time utilization U_v (paper §4 per-processor
    /// averaging) through the Pallas reduction: per-processor mean task
    /// times (≤ P=2048 entries) + marginal latency → U.
    pub fn u_variable(&mut self, per_proc_mean_t: &[f64], t_s: f64) -> Result<f64> {
        use shapes::UVAR_P;
        ensure!(
            !per_proc_mean_t.is_empty() && per_proc_mean_t.len() <= UVAR_P,
            "need 1..={UVAR_P} processors, got {}",
            per_proc_mean_t.len()
        );
        ensure!(
            per_proc_mean_t.iter().all(|&t| t > 0.0),
            "per-processor mean task times must be positive"
        );
        let mut tp = vec![0f32; UVAR_P];
        let mut mask = vec![0f32; UVAR_P];
        for (i, &t) in per_proc_mean_t.iter().enumerate() {
            tp[i] = t as f32;
            mask[i] = 1.0;
        }
        let inputs = [
            PjrtRuntime::literal_f32(&tp, &[UVAR_P as i64])?,
            PjrtRuntime::literal_f32(&mask, &[UVAR_P as i64])?,
            PjrtRuntime::literal_f32(&[t_s as f32], &[1])?,
        ];
        let out = self.rt.load("uvar")?.run_f32(&inputs)?;
        ensure!(out.len() == 1 && out[0].len() == 1, "uvar returns a scalar");
        Ok(out[0][0] as f64)
    }

    /// PJRT platform name.
    pub fn platform(&self) -> String {
        self.rt.platform()
    }
}
