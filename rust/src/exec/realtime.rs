//! Leader/worker realtime coordinator.

use crate::sched::RunResult;
use crate::util::prng::Prng;
use crate::util::stats::{condense_sample, percentile_sorted, Summary, WAIT_SAMPLE_CAP};
use crate::workload::TraceRecord;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// What a realtime task executes.
#[derive(Clone, Debug)]
pub enum RtWork {
    /// Block the worker for the given seconds (paper's sleep benchmark).
    Sleep(f64),
    /// Spin-wait (busy CPU) for the given seconds.
    Spin(f64),
    /// Run `batches` invocations of the AOT analytics payload via PJRT.
    Analytics {
        /// Number of (B, D) batches to process.
        batches: u32,
        /// Data seed.
        seed: u64,
    },
}

/// One realtime task.
#[derive(Clone, Debug)]
pub struct RtTask {
    /// Dense id.
    pub id: u32,
    /// Nominal isolated duration (s) — used for T_job accounting, like
    /// the constant task time t of the paper's benchmark.
    pub nominal: f64,
    /// Payload.
    pub work: RtWork,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct RealtimeParams {
    /// Worker thread count P.
    pub workers: usize,
    /// Serial dispatch overhead injected at the leader per task (s) —
    /// the emulated marginal scheduler latency t_s. 0 to measure the
    /// coordinator's intrinsic overhead.
    pub dispatch_overhead: f64,
    /// Artifacts directory for `RtWork::Analytics` (None disables PJRT;
    /// Analytics tasks then fail).
    pub artifacts_dir: Option<String>,
}

impl Default for RealtimeParams {
    fn default() -> Self {
        Self {
            workers: 4,
            dispatch_overhead: 0.0,
            artifacts_dir: None,
        }
    }
}

/// The realtime mini-cluster.
pub struct RealtimeCoordinator {
    params: RealtimeParams,
}

struct Completion {
    task: u32,
    worker: u32,
    start_s: f64,
    end_s: f64,
    checksum: f64,
}

impl RealtimeCoordinator {
    /// New coordinator.
    pub fn new(params: RealtimeParams) -> Self {
        Self { params }
    }

    /// Execute all tasks; returns a [`RunResult`] in wall-clock seconds
    /// plus the per-task trace. (String-typed error — the offline crate
    /// set has no `anyhow`.)
    pub fn run(&self, tasks: &[RtTask]) -> Result<RunResult, String> {
        let p = self.params.workers.max(1);
        let epoch = Instant::now();
        let (done_tx, done_rx) = mpsc::channel::<Completion>();

        // One channel per worker.
        let mut task_txs = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for w in 0..p {
            let (tx, rx) = mpsc::channel::<RtTask>();
            task_txs.push(tx);
            let done = done_tx.clone();
            let artifacts = self.params.artifacts_dir.clone();
            let h = std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || worker_loop(w as u32, rx, done, artifacts, epoch))
                .expect("spawn worker");
            handles.push(h);
        }
        drop(done_tx);

        // Leader loop: initial wave, then dispatch-on-completion with the
        // configured serial overhead (the emulated t_s).
        let mut pending: std::collections::VecDeque<RtTask> =
            tasks.iter().cloned().collect();
        let mut free: Vec<u32> = (0..p as u32).rev().collect();
        let mut outstanding = 0usize;
        let mut waits = Summary::new();
        let mut wait_list: Vec<f64> = Vec::with_capacity(tasks.len());
        let mut trace: Vec<TraceRecord> = Vec::with_capacity(tasks.len());
        let mut makespan = 0.0f64;
        let mut checksum_acc = 0.0f64;

        loop {
            // Dispatch as long as there are free workers and tasks.
            while let (Some(&worker), false) = (free.last(), pending.is_empty()) {
                let task = pending.pop_front().unwrap();
                free.pop();
                // The emulated daemon latency blocks the leader (serial
                // dispatch) without burning a core the workers need.
                wait_for(self.params.dispatch_overhead);
                let wait = epoch.elapsed().as_secs_f64();
                waits.add(wait);
                wait_list.push(wait);
                task_txs[worker as usize]
                    .send(task)
                    .expect("worker channel closed");
                outstanding += 1;
            }
            if outstanding == 0 && pending.is_empty() {
                break;
            }
            let c = done_rx.recv().expect("completion channel closed");
            outstanding -= 1;
            free.push(c.worker);
            makespan = makespan.max(c.end_s);
            checksum_acc += c.checksum;
            trace.push(TraceRecord {
                task: c.task,
                node: c.worker,
                slot: c.worker,
                submit: 0.0,
                start: c.start_s,
                end: c.end_s,
            });
        }

        drop(task_txs);
        for h in handles {
            h.join().expect("worker panicked");
        }
        // Checksums keep the analytics work observable (no dead-code
        // elimination concerns, and a cheap integrity signal).
        let _ = checksum_acc;

        let t_job: f64 = tasks.iter().map(|t| t.nominal).sum::<f64>() / p as f64;
        trace.sort_by_key(|r| r.task);
        // Realtime runs are small: exact quantiles from the full sorted
        // wait list, condensed to the same bounded-sample contract the
        // simulator's streaming reservoir honors.
        wait_list.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| {
            if wait_list.is_empty() {
                f64::NAN
            } else {
                percentile_sorted(&wait_list, p)
            }
        };
        let (wait_p50, wait_p95, wait_p99) = (q(0.50), q(0.95), q(0.99));
        condense_sample(&mut wait_list, WAIT_SAMPLE_CAP);
        Ok(RunResult {
            scheduler: format!("realtime(ts={})", self.params.dispatch_overhead),
            workload: "realtime".into(),
            n_tasks: tasks.len() as u64,
            processors: p as u64,
            t_total: makespan,
            t_job,
            events: 0,
            daemon_busy: self.params.dispatch_overhead * tasks.len() as f64,
            waits,
            wait_p50,
            wait_p95,
            wait_p99,
            wait_sample: wait_list,
            preemptions: 0,
            kills: 0,
            failed: 0,
            completed: tasks.len() as u64,
            wasted_core_seconds: 0.0,
            horizon: None,
            busy_core_seconds: 0.0,
            detection_latencies: Vec::new(),
            undetected_lost_core_seconds: 0.0,
            messages_lost: 0,
            messages_duplicated: 0,
            spec_launches: 0,
            spec_kills: 0,
            retry_hist: Vec::new(),
            trace: Some(trace),
            spans: None,
        })
    }
}

fn worker_loop(
    id: u32,
    rx: mpsc::Receiver<RtTask>,
    done: mpsc::Sender<Completion>,
    artifacts: Option<String>,
    epoch: Instant,
) {
    // PJRT client created inside the worker thread (the xla handles are
    // not Send; each worker owns its own). Eager load keeps artifact
    // compilation out of the timed path.
    let mut suite = artifacts.as_deref().map(|dir| {
        crate::runtime::ArtifactSuite::load(dir).expect("load artifacts")
    });
    while let Ok(task) = rx.recv() {
        let start_s = epoch.elapsed().as_secs_f64();
        let mut checksum = 0.0f64;
        match task.work {
            RtWork::Sleep(s) => std::thread::sleep(Duration::from_secs_f64(s)),
            RtWork::Spin(s) => spin_for(s),
            RtWork::Analytics { batches, seed } => {
                let suite = suite
                    .as_mut()
                    .expect("Analytics task needs artifacts_dir");
                let mut rng = Prng::new(seed ^ (id as u64) << 32 ^ task.id as u64);
                use crate::runtime::shapes::{ANALYTICS_B, ANALYTICS_D, ANALYTICS_F};
                for _ in 0..batches {
                    let x: Vec<f32> = (0..ANALYTICS_B * ANALYTICS_D)
                        .map(|_| rng.f64() as f32 - 0.5)
                        .collect();
                    let w: Vec<f32> = (0..ANALYTICS_D * ANALYTICS_F)
                        .map(|_| rng.f64() as f32 - 0.5)
                        .collect();
                    let (_, c) = suite.analytics(&x, &w).expect("analytics exec");
                    checksum += c as f64;
                }
            }
        }
        let end_s = epoch.elapsed().as_secs_f64();
        if done
            .send(Completion {
                task: task.id,
                worker: id,
                start_s,
                end_s,
                checksum,
            })
            .is_err()
        {
            return; // leader gone
        }
    }
}

/// Block for `s` seconds: sleep for multi-millisecond waits, spin below
/// (where sleep would overshoot).
fn wait_for(s: f64) {
    if s > 0.002 {
        std::thread::sleep(Duration::from_secs_f64(s));
    } else {
        spin_for(s);
    }
}

/// Busy-wait for `s` seconds (sub-millisecond precision where sleep
/// would overshoot).
fn spin_for(s: f64) {
    if s <= 0.0 {
        return;
    }
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < s {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sleep_tasks(n: u32, dur: f64) -> Vec<RtTask> {
        (0..n)
            .map(|id| RtTask {
                id,
                nominal: dur,
                work: RtWork::Sleep(dur),
            })
            .collect()
    }

    #[test]
    fn executes_all_tasks_in_parallel() {
        let coord = RealtimeCoordinator::new(RealtimeParams {
            workers: 4,
            ..Default::default()
        });
        let r = coord.run(&sleep_tasks(16, 0.02)).unwrap();
        r.check_invariants().unwrap();
        assert_eq!(r.n_tasks, 16);
        // 16 × 20 ms on 4 workers ≈ 80 ms ideal; allow generous slack.
        assert!(r.t_total >= 0.079, "t_total={}", r.t_total);
        assert!(r.t_total < 0.5, "t_total={}", r.t_total);
        // All 4 workers used.
        let trace = r.trace.as_ref().unwrap();
        let mut workers: Vec<u32> = trace.iter().map(|t| t.node).collect();
        workers.sort_unstable();
        workers.dedup();
        assert_eq!(workers.len(), 4);
    }

    #[test]
    fn dispatch_overhead_degrades_utilization() {
        let fast = RealtimeCoordinator::new(RealtimeParams {
            workers: 2,
            dispatch_overhead: 0.0,
            artifacts_dir: None,
        });
        let slow = RealtimeCoordinator::new(RealtimeParams {
            workers: 2,
            dispatch_overhead: 0.02,
            artifacts_dir: None,
        });
        let tasks = sleep_tasks(20, 0.01);
        let u_fast = fast.run(&tasks).unwrap().utilization();
        let u_slow = slow.run(&tasks).unwrap().utilization();
        assert!(
            u_slow < u_fast * 0.8,
            "u_slow={u_slow} should trail u_fast={u_fast}"
        );
    }

    #[test]
    fn spin_work_supported() {
        let coord = RealtimeCoordinator::new(RealtimeParams {
            workers: 2,
            ..Default::default()
        });
        let tasks: Vec<RtTask> = (0..4)
            .map(|id| RtTask {
                id,
                nominal: 0.005,
                work: RtWork::Spin(0.005),
            })
            .collect();
        let r = coord.run(&tasks).unwrap();
        assert!(r.t_total >= 0.0099, "t_total={}", r.t_total);
    }

    #[test]
    fn empty_task_list_is_fine() {
        let coord = RealtimeCoordinator::new(RealtimeParams::default());
        let r = coord.run(&[]).unwrap();
        assert_eq!(r.n_tasks, 0);
        assert_eq!(r.t_total, 0.0);
    }
}
