//! Realtime execution mode: a real (wall-clock) mini-cluster.
//!
//! Where `sim/` reproduces the paper's 1408-core measurements in virtual
//! time, this module actually runs tasks: a leader thread owns the
//! pending queue and dispatches over channels to P worker threads;
//! workers execute either a timed spin/sleep task (the paper's `sleep`
//! benchmark payload) or the real AOT-compiled analytics kernel through
//! PJRT (the "data analysis job"). A configurable serial dispatch
//! overhead plays the role of the scheduler's marginal latency t_s, so
//! the measured wall-clock utilization curves can be compared directly
//! against the paper's U_c(t) model — on real hardware, end to end.

mod realtime;

pub use realtime::{RealtimeCoordinator, RealtimeParams, RtTask, RtWork};
