//! Fitting measured runs to the latency model (the measurement side of
//! Table 10). The same fit is also available through the AOT-compiled
//! Pallas kernel (`artifacts/powerlaw_fit.hlo.txt`); `rust/tests/`
//! cross-checks the two paths agree.

use crate::sched::RunResult;
use crate::util::fit::{fit_power_law, PowerLawFit};

/// One (n, ΔT) observation from a run.
#[derive(Clone, Copy, Debug)]
pub struct FitPoint {
    /// Tasks per processor n.
    pub n: f64,
    /// Measured non-execution latency ΔT (s).
    pub delta_t: f64,
}

impl FitPoint {
    /// Extract from a run result.
    pub fn from_run(r: &RunResult) -> Self {
        Self {
            n: r.tasks_per_proc(),
            delta_t: r.delta_t(),
        }
    }
}

/// Fit ΔT = t_s n^α_s over a set of runs (all trials pooled, like the
/// paper's per-scheduler fit over the Table 9 task sets).
pub fn fit_from_runs<'a>(runs: impl IntoIterator<Item = &'a RunResult>) -> PowerLawFit {
    let pts: Vec<FitPoint> = runs.into_iter().map(FitPoint::from_run).collect();
    let ns: Vec<f64> = pts.iter().map(|p| p.n).collect();
    let dts: Vec<f64> = pts.iter().map(|p| p.delta_t).collect();
    fit_power_law(&ns, &dts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn synthetic_run(n: f64, t_s: f64, alpha: f64) -> RunResult {
        let p = 1408u64;
        let t_job = 240.0;
        RunResult {
            scheduler: "syn".into(),
            workload: "syn".into(),
            n_tasks: (n * p as f64) as u64,
            processors: p,
            t_total: t_job + t_s * n.powf(alpha),
            t_job,
            events: 0,
            daemon_busy: 0.0,
            waits: Summary::new(),
            wait_p50: f64::NAN,
            wait_p95: f64::NAN,
            wait_p99: f64::NAN,
            wait_sample: Vec::new(),
            preemptions: 0,
            kills: 0,
            failed: 0,
            completed: (n * p as f64) as u64,
            wasted_core_seconds: 0.0,
            horizon: None,
            busy_core_seconds: 0.0,
            detection_latencies: Vec::new(),
            undetected_lost_core_seconds: 0.0,
            messages_lost: 0,
            messages_duplicated: 0,
            spec_launches: 0,
            spec_kills: 0,
            retry_hist: Vec::new(),
            trace: None,
            spans: None,
        }
    }

    #[test]
    fn recovers_synthetic_parameters() {
        let runs: Vec<RunResult> = [4.0, 8.0, 48.0, 240.0]
            .iter()
            .map(|&n| synthetic_run(n, 2.8, 1.3))
            .collect();
        let fit = fit_from_runs(&runs);
        assert!((fit.t_s - 2.8).abs() < 1e-6, "t_s={}", fit.t_s);
        assert!((fit.alpha_s - 1.3).abs() < 1e-6);
    }

    #[test]
    fn pooled_trials_average_out() {
        // Three noisy trials per n: fit should still land close.
        let mut runs = Vec::new();
        for &n in &[4.0, 8.0, 48.0, 240.0] {
            for tweak in [0.95, 1.0, 1.05] {
                let mut r = synthetic_run(n, 3.4, 1.1);
                r.t_total = r.t_job + (r.t_total - r.t_job) * tweak;
                runs.push(r);
            }
        }
        let fit = fit_from_runs(&runs);
        assert!((fit.t_s - 3.4).abs() < 0.3);
        assert!((fit.alpha_s - 1.1).abs() < 0.05);
    }
}
