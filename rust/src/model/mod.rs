//! The paper's Section 4 analytic latency and utilization models, and
//! the measurement-side fitting that produces Table 10.
//!
//! Notation (paper Table 8): t_s marginal scheduler latency, t task
//! time, n tasks per processor, α_s nonlinear exponent, U utilization.

mod analytic;
mod fitted;
mod measure;

pub use analytic::{delta_t_model, u_constant_approx, u_constant_exact, u_variable};
pub use fitted::{
    derive_bundle_size, expected_bundle_overhead, fit_sweep, predicted_bundled_utilization,
    BundleChoice, FittedModel, ZERO_DELTA_T,
};
pub use measure::{fit_from_runs, FitPoint};
