//! Closed-form model equations from Section 4 of the paper.

/// ΔT = t_s · n^α_s — the non-execution latency model.
pub fn delta_t_model(t_s: f64, alpha_s: f64, n: f64) -> f64 {
    t_s * n.powf(alpha_s)
}

/// Approximate constant-task-time utilization (paper: valid for
/// α_s ≈ 1): `U_c(t)^-1 ≈ 1 + t_s/t` — the dotted model lines of
/// Figure 5a.
pub fn u_constant_approx(t_s: f64, t: f64) -> f64 {
    assert!(t > 0.0);
    1.0 / (1.0 + t_s / t)
}

/// Exact constant-task-time utilization:
/// `U_c^-1 = 1 + (t_s n^α_s)/(t n)` — the dashed model lines of
/// Figure 5b.
pub fn u_constant_exact(t_s: f64, alpha_s: f64, t: f64, n: f64) -> f64 {
    assert!(t > 0.0 && n > 0.0);
    1.0 / (1.0 + t_s * n.powf(alpha_s) / (t * n))
}

/// Variable-task-time utilization via per-processor averaging:
/// `U^-1 ≈ P^-1 Σ_p U_c(t(p))^-1`, where t(p) is the average duration
/// of tasks on processor p. `per_proc_mean_t` carries one entry per
/// processor.
pub fn u_variable(t_s: f64, per_proc_mean_t: &[f64]) -> f64 {
    assert!(!per_proc_mean_t.is_empty());
    let inv_sum: f64 = per_proc_mean_t
        .iter()
        .map(|&tp| 1.0 / u_constant_approx(t_s, tp))
        .sum();
    per_proc_mean_t.len() as f64 / inv_sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t_equals_ts_gives_half_utilization() {
        // Paper: t_s ≈ t ⇒ U_c ≈ 0.5.
        assert!((u_constant_approx(2.0, 2.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn exact_reduces_to_approx_at_alpha_one() {
        let (t_s, t, n) = (2.2, 5.0, 48.0);
        let exact = u_constant_exact(t_s, 1.0, t, n);
        let approx = u_constant_approx(t_s, t);
        assert!((exact - approx).abs() < 1e-12);
    }

    #[test]
    fn alpha_above_one_hurts_utilization_at_high_n() {
        let u1 = u_constant_exact(2.2, 1.0, 1.0, 240.0);
        let u13 = u_constant_exact(2.2, 1.3, 1.0, 240.0);
        assert!(u13 < u1);
    }

    #[test]
    fn long_tasks_approach_full_utilization() {
        assert!(u_constant_approx(2.2, 3600.0) > 0.999);
        assert!(u_constant_approx(2.2, 1.0) < 0.32);
    }

    #[test]
    fn variable_equals_constant_for_uniform_tasks() {
        let u_var = u_variable(2.2, &[5.0; 100]);
        let u_c = u_constant_approx(2.2, 5.0);
        assert!((u_var - u_c).abs() < 1e-12);
    }

    #[test]
    fn variable_mixture_between_extremes() {
        // Half the processors run 1 s tasks, half run 60 s tasks.
        let mut ts = vec![1.0; 50];
        ts.extend(vec![60.0; 50]);
        let u = u_variable(2.2, &ts);
        assert!(u > u_constant_approx(2.2, 1.0));
        assert!(u < u_constant_approx(2.2, 60.0));
    }

    #[test]
    fn delta_t_matches_table10_slurm() {
        // Slurm at n=240: 2.2 · 240^1.3 ≈ 2731 s.
        let dt = delta_t_model(2.2, 1.3, 240.0);
        assert!((dt - 2731.0).abs() < 15.0, "dt={dt}");
    }
}
