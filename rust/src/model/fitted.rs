//! The fitted-model layer: harden per-backend sweep data into
//! `(t_s, α_s, r²)` and close the paper's loop — invert the analytic
//! utilization model to *derive* the multilevel bundle size whose
//! predicted short-task utilization meets a target, instead of
//! hand-setting one mapper per processor.
//!
//! Fitting goes through [`crate::util::fit::try_fit_power_law`], so a
//! pathological sweep row (single usable n, all-zero ΔT on a noisy
//! backend, every n skipped as prohibitive) surfaces as a contextual
//! error the experiment gate can report, not a process abort.

use super::analytic::u_constant_exact;
use crate::multilevel::{MapMode, MultilevelParams};
use crate::util::fit::try_fit_power_law;

/// ΔT at or below this is indistinguishable from zero overhead — it is
/// floating-point noise on a backend whose waves are exact (the ideal
/// FIFO reference lands here).
pub const ZERO_DELTA_T: f64 = 1e-6;

/// A per-backend fit of ΔT = t_s · n^α_s with its provenance.
#[derive(Clone, Debug)]
pub struct FittedModel {
    /// Marginal scheduler latency t_s (seconds).
    pub t_s: f64,
    /// Nonlinear exponent α_s.
    pub alpha_s: f64,
    /// R² of the log–log fit (1.0 for the zero-overhead convention).
    pub r2: f64,
    /// True when every sweep ΔT was ≤ [`ZERO_DELTA_T`]: the backend has
    /// no measurable launch overhead and (t_s, α_s) = (0, 1) by
    /// convention. Such rows are exempt from the r² gate.
    pub zero_overhead: bool,
    /// Pooled (n, ΔT) observations the fit consumed.
    pub points: usize,
    /// Smallest n in the sweep.
    pub n_lo: f64,
    /// Largest n in the sweep.
    pub n_hi: f64,
}

impl FittedModel {
    /// Evaluate the fitted model ΔT(n).
    pub fn delta_t(&self, n: f64) -> f64 {
        self.t_s * n.powf(self.alpha_s)
    }
}

/// Fit pooled `(n, ΔT)` sweep observations for one backend. The error
/// carries the scheduler name and n-range so a gate failure reads as a
/// diagnostic ("which row, which sweep") rather than a bare statistic.
pub fn fit_sweep(scheduler: &str, points: &[(f64, f64)]) -> Result<FittedModel, String> {
    if points.is_empty() {
        return Err(format!(
            "{scheduler}: no sweep points to fit (every n skipped as prohibitive?)"
        ));
    }
    let n_lo = points.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
    let n_hi = points.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max);
    if points.iter().all(|&(_, dt)| dt <= ZERO_DELTA_T) {
        return Ok(FittedModel {
            t_s: 0.0,
            alpha_s: 1.0,
            r2: 1.0,
            zero_overhead: true,
            points: points.len(),
            n_lo,
            n_hi,
        });
    }
    // Drop sub-noise points before the log–log fit: ln of an fp-noise
    // ΔT would swing the regression by tens of decades.
    let usable: Vec<(f64, f64)> = points
        .iter()
        .copied()
        .filter(|&(_, dt)| dt > ZERO_DELTA_T)
        .collect();
    let ns: Vec<f64> = usable.iter().map(|p| p.0).collect();
    let dts: Vec<f64> = usable.iter().map(|p| p.1).collect();
    match try_fit_power_law(&ns, &dts) {
        Ok(f) => Ok(FittedModel {
            t_s: f.t_s,
            alpha_s: f.alpha_s,
            r2: f.r2,
            zero_overhead: false,
            points: usable.len(),
            n_lo,
            n_hi,
        }),
        Err(e) => Err(format!(
            "{scheduler}: power-law fit over n in [{n_lo}, {n_hi}] ({} of {} points usable) \
             failed: {e}",
            usable.len(),
            points.len(),
        )),
    }
}

/// Expected (mean, jitter-free) mapper overhead of one bundle of `k`
/// input tasks under `params` — the deterministic counterpart of
/// [`crate::multilevel::Multilevel::aggregate`]'s lognormal draws.
pub fn expected_bundle_overhead(params: &MultilevelParams, k: f64) -> f64 {
    match params.mode {
        MapMode::Mimo => params.mapper_startup + k * params.per_input_overhead,
        MapMode::Siso => params.mapper_startup + k * params.app_startup,
    }
}

/// Predicted utilization of an n-tasks-per-processor constant-time
/// workload (task time `t`) aggregated into `m` bundles per processor
/// under a backend with fitted `(t_s, α_s)`.
///
/// The aggregated run is itself a constant-task-time workload — m tasks
/// per processor of duration t_eff = (n/m)·t + ovh(n/m) — so
/// [`u_constant_exact`] gives its busy fraction; multiplying by the
/// useful share (n/m)·t / t_eff re-bases to the ORIGINAL job time,
/// counting mapper overheads as waste, exactly the Figure 6/7
/// accounting that `Multilevel` reports.
pub fn predicted_bundled_utilization(
    t_s: f64,
    alpha_s: f64,
    params: &MultilevelParams,
    t: f64,
    n: f64,
    m: f64,
) -> f64 {
    assert!(t > 0.0 && n > 0.0 && m >= 1.0 && m <= n);
    let k = n / m;
    let useful = k * t;
    let t_eff = useful + expected_bundle_overhead(params, k);
    u_constant_exact(t_s, alpha_s, t_eff, m) * (useful / t_eff)
}

/// The auto-tuner's answer for one backend.
#[derive(Clone, Copy, Debug)]
pub struct BundleChoice {
    /// Bundles per processor m (the aggregate call gets m·P bundles).
    pub bundles_per_proc: u32,
    /// Derived bundle size ⌈n/m⌉ in original tasks.
    pub bundle_size: u64,
    /// Predicted utilization at that choice.
    pub predicted_u: f64,
    /// True when even one bundle per processor cannot reach the target;
    /// the choice is then the best achievable, m = 1.
    pub capped: bool,
}

/// Smallest bundle size — i.e. the largest bundles-per-processor
/// m ∈ [1, n] — whose predicted utilization is ≥ `target`.
///
/// Predicted U is monotone non-increasing in m (the denominator
/// n·t + m·mapper_startup + per-input terms + t_s·m^α_s only grows
/// with m), so the first qualifying m scanning downward from n is the
/// optimum. Integer m keeps every processor on exactly m equal-shape
/// bundles; a fractional bundles-per-processor count would quantize
/// into unequal waves and the simulation would fall measurably short
/// of this prediction.
pub fn derive_bundle_size(
    t_s: f64,
    alpha_s: f64,
    params: &MultilevelParams,
    t: f64,
    n: u32,
    target: f64,
) -> BundleChoice {
    assert!(n >= 1, "need at least one task per processor");
    assert!(
        target.is_finite() && target > 0.0 && target < 1.0,
        "target utilization must be in (0, 1)"
    );
    for m in (1..=n).rev() {
        let u = predicted_bundled_utilization(t_s, alpha_s, params, t, n as f64, m as f64);
        if u >= target {
            return BundleChoice {
                bundles_per_proc: m,
                bundle_size: (n as u64).div_ceil(m as u64),
                predicted_u: u,
                capped: false,
            };
        }
    }
    BundleChoice {
        bundles_per_proc: 1,
        bundle_size: n as u64,
        predicted_u: predicted_bundled_utilization(t_s, alpha_s, params, t, n as f64, 1.0),
        capped: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_sweep_exact_recovery() {
        let pts: Vec<(f64, f64)> = [4.0f64, 8.0, 48.0, 240.0]
            .iter()
            .map(|&n| (n, 2.2 * n.powf(1.3)))
            .collect();
        let f = fit_sweep("Slurm", &pts).unwrap();
        assert!((f.t_s - 2.2).abs() < 1e-9);
        assert!((f.alpha_s - 1.3).abs() < 1e-9);
        assert!(!f.zero_overhead);
        assert_eq!(f.points, 4);
        assert_eq!((f.n_lo, f.n_hi), (4.0, 240.0));
    }

    #[test]
    fn fit_sweep_zero_overhead_convention() {
        let pts = [(4.0, 0.0), (8.0, 1e-10), (48.0, 0.0)];
        let f = fit_sweep("IdealFIFO", &pts).unwrap();
        assert!(f.zero_overhead);
        assert_eq!((f.t_s, f.alpha_s, f.r2), (0.0, 1.0, 1.0));
        assert_eq!(f.delta_t(240.0), 0.0);
    }

    #[test]
    fn fit_sweep_errors_carry_context() {
        let e = fit_sweep("WeirdSched", &[]).unwrap_err();
        assert!(e.contains("WeirdSched"), "{e}");
        // One usable point out of three: too few, with scheduler +
        // n-range context in the message.
        let e = fit_sweep("WeirdSched", &[(4.0, 0.0), (8.0, 0.0), (48.0, 3.0)]).unwrap_err();
        assert!(e.contains("WeirdSched") && e.contains("[4, 48]"), "{e}");
        // Repeated trials at a single n: degenerate x.
        let e = fit_sweep("WeirdSched", &[(8.0, 3.0), (8.0, 3.1)]).unwrap_err();
        assert!(e.contains("degenerate"), "{e}");
    }

    #[test]
    fn predicted_u_monotone_in_m() {
        let p = MultilevelParams::default();
        let mut last = f64::INFINITY;
        for m in 1..=960u32 {
            let u = predicted_bundled_utilization(2.2, 1.3, &p, 1.0, 960.0, m as f64);
            assert!(u <= last + 1e-12, "m={m}: {u} > {last}");
            assert!(u > 0.0 && u <= 1.0);
            last = u;
        }
    }

    #[test]
    fn predicted_u_inverts_u_constant_exact_when_overhead_free() {
        // With zero mapper overhead the re-basing factor is 1 and the
        // prediction IS the analytic model at (t_eff = k·t, n = m).
        let p = MultilevelParams {
            mapper_startup: 0.0,
            per_input_overhead: 0.0,
            ..MultilevelParams::default()
        };
        let (t_s, a, t, n, m) = (3.4, 1.1, 2.0, 240.0, 12.0);
        let got = predicted_bundled_utilization(t_s, a, &p, t, n, m);
        let want = u_constant_exact(t_s, a, (n / m) * t, m);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn derive_picks_largest_qualifying_m() {
        let p = MultilevelParams::default();
        let c = derive_bundle_size(2.2, 1.3, &p, 1.0, 960, 0.9);
        assert!(!c.capped);
        // The chosen m meets the target; m + 1 must not.
        let at = |m: f64| predicted_bundled_utilization(2.2, 1.3, &p, 1.0, 960.0, m);
        assert!(c.predicted_u >= 0.9);
        assert!(at(c.bundles_per_proc as f64 + 1.0) < 0.9);
        assert_eq!(c.bundle_size, 960u64.div_ceil(c.bundles_per_proc as u64));
    }

    #[test]
    fn derive_caps_at_one_bundle_when_target_unreachable() {
        let p = MultilevelParams::default();
        // A pathologically slow scheduler: even a single bundle per
        // processor cannot reach 90 %.
        let c = derive_bundle_size(1.0e6, 1.3, &p, 1.0, 960, 0.9);
        assert!(c.capped);
        assert_eq!(c.bundles_per_proc, 1);
        assert_eq!(c.bundle_size, 960);
        assert!(c.predicted_u < 0.9);
    }

    #[test]
    fn zero_overhead_backend_takes_smallest_bundles() {
        // t_s = 0 and free mappers would allow m = n; with the default
        // mapper costs the per-bundle startup alone bounds m.
        let p = MultilevelParams::default();
        let c = derive_bundle_size(0.0, 1.0, &p, 1.0, 960, 0.9);
        assert!(!c.capped);
        assert!(c.bundles_per_proc >= 32, "m={}", c.bundles_per_proc);
    }

    #[test]
    fn siso_overhead_exceeds_mimo_in_expectation() {
        let mimo = MultilevelParams::default();
        let siso = MultilevelParams {
            mode: MapMode::Siso,
            ..MultilevelParams::default()
        };
        assert!(expected_bundle_overhead(&siso, 40.0) > expected_bundle_overhead(&mimo, 40.0));
    }
}
