//! The feature matrix data and its rendering.

use crate::util::table::Table;

/// The eight representative schedulers of Section 3.3, in table order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerInfo {
    /// IBM Platform LSF.
    Lsf,
    /// OpenLAVA (open-source LSF derivative).
    OpenLava,
    /// Slurm.
    Slurm,
    /// Grid Engine (Univa / Son of Grid Engine).
    GridEngine,
    /// Pacora (research scheduler).
    Pacora,
    /// Apache Hadoop YARN.
    Yarn,
    /// Apache Mesos.
    Mesos,
    /// Google Kubernetes.
    Kubernetes,
}

impl SchedulerInfo {
    /// All eight, in the paper's column order.
    pub fn all() -> [SchedulerInfo; 8] {
        use SchedulerInfo::*;
        [Lsf, OpenLava, Slurm, GridEngine, Pacora, Yarn, Mesos, Kubernetes]
    }

    /// Column header.
    pub fn name(&self) -> &'static str {
        use SchedulerInfo::*;
        match self {
            Lsf => "LSF",
            OpenLava => "OpenLAVA",
            Slurm => "Slurm",
            GridEngine => "Grid Engine",
            Pacora => "Pacora",
            Yarn => "YARN",
            Mesos => "Mesos",
            Kubernetes => "Kubernetes",
        }
    }

    /// HPC or Big Data family (Table 1 "Type" row).
    pub fn family(&self) -> &'static str {
        use SchedulerInfo::*;
        match self {
            Lsf | OpenLava | Slurm | GridEngine | Pacora => "HPC",
            Yarn | Mesos | Kubernetes => "Big Data",
        }
    }
}

/// A cell in the feature matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureValue {
    /// Supported (✓).
    Yes,
    /// Not supported (blank in the paper).
    No,
    /// Supported with a caveat (footnotes in the paper).
    Partial(&'static str),
    /// Not applicable / unknown (— for Pacora).
    NA,
    /// Free-text cell (e.g. "Open source", "10K+").
    Text(&'static str),
}

impl FeatureValue {
    /// Render for tables.
    pub fn render(&self) -> String {
        match self {
            FeatureValue::Yes => "yes".into(),
            FeatureValue::No => "".into(),
            FeatureValue::Partial(note) => format!("yes*({note})"),
            FeatureValue::NA => "-".into(),
            FeatureValue::Text(t) => (*t).into(),
        }
    }

    /// True for Yes/Partial.
    pub fn supported(&self) -> bool {
        matches!(self, FeatureValue::Yes | FeatureValue::Partial(_))
    }
}

/// The seven table categories of Section 3.3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureCategory {
    /// Table 1.
    Metadata,
    /// Table 2.
    JobTypes,
    /// Table 3.
    JobScheduling,
    /// Table 4.
    ResourceManagement,
    /// Table 5.
    JobPlacement,
    /// Table 6.
    SchedulingPerformance,
    /// Table 7.
    JobExecution,
}

impl FeatureCategory {
    /// All, in paper table order (1..=7).
    pub fn all() -> [FeatureCategory; 7] {
        use FeatureCategory::*;
        [
            Metadata,
            JobTypes,
            JobScheduling,
            ResourceManagement,
            JobPlacement,
            SchedulingPerformance,
            JobExecution,
        ]
    }

    /// Paper table number.
    pub fn table_number(&self) -> u32 {
        Self::all().iter().position(|c| c == self).unwrap() as u32 + 1
    }

    /// Table title.
    pub fn title(&self) -> &'static str {
        use FeatureCategory::*;
        match self {
            Metadata => "Table 1: Metadata features",
            JobTypes => "Table 2: Job type features",
            JobScheduling => "Table 3: Job scheduling features",
            ResourceManagement => "Table 4: Resource management features",
            JobPlacement => "Table 5: Job placement features",
            SchedulingPerformance => "Table 6: Scheduling performance features",
            JobExecution => "Table 7: Job execution features",
        }
    }
}

/// One feature row: name, category, and the eight scheduler cells in
/// [`SchedulerInfo::all`] order.
pub struct FeatureRow {
    /// Row label.
    pub name: &'static str,
    /// Which paper table it belongs to.
    pub category: FeatureCategory,
    /// Cells for the eight schedulers.
    pub values: [FeatureValue; 8],
}

use FeatureCategory as C;
use FeatureValue::{No, Partial, Text, Yes, NA};

/// The full matrix, rows in paper order. Cell order:
/// LSF, OpenLAVA, Slurm, Grid Engine, Pacora, YARN, Mesos, Kubernetes.
pub fn all_features() -> Vec<FeatureRow> {
    vec![
        // ------------------------------------------------ Table 1
        FeatureRow {
            name: "Type",
            category: C::Metadata,
            values: [
                Text("HPC"), Text("HPC"), Text("HPC"), Text("HPC"), Text("HPC"),
                Text("Big Data"), Text("Big Data"), Text("Big Data"),
            ],
        },
        FeatureRow {
            name: "Actively developed",
            category: C::Metadata,
            values: [Yes, Yes, Yes, Yes, Partial("within Microsoft"), Yes, Yes, Yes],
        },
        FeatureRow {
            name: "Cost / licensing",
            category: C::Metadata,
            values: [
                Text("$$$"), Text("Open source"), Text("Open source"),
                Text("$$$, Open source"), Text("N/A"), Text("Open source"),
                Text("Open source"), Text("Open source"),
            ],
        },
        FeatureRow {
            name: "OS support",
            category: C::Metadata,
            values: [
                Text("Linux"), Text("Linux, Cygwin"), Text("Linux, *nix"),
                Text("Linux, *nix"), Text("N/A"), Text("Linux"), Text("Linux"),
                Text("Linux"),
            ],
        },
        FeatureRow {
            name: "Language support",
            category: C::Metadata,
            values: [
                Text("All"), Text("All"), Text("All"), Text("All"), Text("N/A"),
                Text("Java, Python (strongest)"), Text("All"), Text("All"),
            ],
        },
        FeatureRow {
            name: "Access control / security",
            category: C::Metadata,
            values: [Yes, Yes, Yes, Yes, NA, Yes, Yes, Yes],
        },
        // ------------------------------------------------ Table 2
        FeatureRow {
            name: "Parallel and array jobs",
            category: C::JobTypes,
            values: [
                Text("Both"), Text("Both"), Text("Both"), Text("Both"), Text("N/A"),
                Text("Array"), Text("Array"), Text("Array"),
            ],
        },
        FeatureRow {
            name: "Queue support",
            category: C::JobTypes,
            values: [
                Yes, Yes, Yes, Yes, NA,
                Partial("capacity scheduler"),
                Partial("frameworks act as queues"),
                No,
            ],
        },
        FeatureRow {
            name: "Multiple resource managers (metascheduling)",
            category: C::JobTypes,
            values: [No, No, No, No, NA, No, Yes, No],
        },
        // ------------------------------------------------ Table 3
        FeatureRow {
            name: "Timesharing",
            category: C::JobScheduling,
            values: [Yes, Yes, Yes, Yes, NA, Yes, Yes, Yes],
        },
        FeatureRow {
            name: "Backfilling",
            category: C::JobScheduling,
            values: [Yes, Yes, Yes, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "Job chunking",
            category: C::JobScheduling,
            values: [No, No, No, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "Bin packing",
            category: C::JobScheduling,
            values: [No, No, Yes, No, NA, No, No, No],
        },
        FeatureRow {
            name: "Gang scheduling",
            category: C::JobScheduling,
            values: [No, No, Yes, No, NA, No, No, No],
        },
        FeatureRow {
            name: "Job dependencies and DAGs",
            category: C::JobScheduling,
            values: [
                Yes, Yes, Yes, Yes, NA, Yes,
                Partial("if framework supports"),
                No,
            ],
        },
        // ------------------------------------------------ Table 4
        FeatureRow {
            name: "Resource heterogeneity",
            category: C::ResourceManagement,
            values: [Yes, Yes, Yes, Yes, Yes, Yes, Yes, Yes],
        },
        FeatureRow {
            name: "Resource allocation policy",
            category: C::ResourceManagement,
            values: [Yes, Yes, Yes, Yes, Yes, Yes, Yes, Yes],
        },
        FeatureRow {
            name: "Static and dynamic resources",
            category: C::ResourceManagement,
            values: [
                Text("Both"), Text("Both"), Text("Both"), Text("Both"), Text("Both"),
                Text("Both"), Text("Both"), Text("Both"),
            ],
        },
        FeatureRow {
            name: "Network-aware scheduling",
            category: C::ResourceManagement,
            values: [Yes, No, Yes, Yes, NA, No, No, No],
        },
        // ------------------------------------------------ Table 5
        FeatureRow {
            name: "Intelligent scheduling",
            category: C::JobPlacement,
            values: [
                Yes, Yes, Yes, Yes, Yes,
                Partial("Fair/Capacity schedulers"),
                Partial("if framework supports"),
                No,
            ],
        },
        FeatureRow {
            name: "Prioritization schema",
            category: C::JobPlacement,
            values: [Yes, Yes, Yes, Yes, NA, Yes, Yes, Yes],
        },
        FeatureRow {
            name: "Job replacement and reordering",
            category: C::JobPlacement,
            values: [Yes, Yes, Yes, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "Advanced reservations",
            category: C::JobPlacement,
            values: [Yes, No, Yes, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "Power-aware scheduling",
            category: C::JobPlacement,
            values: [Yes, No, Yes, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "User-related job placement",
            category: C::JobPlacement,
            values: [Yes, No, Yes, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "Job-related job placement",
            category: C::JobPlacement,
            values: [Yes, No, Yes, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "Data-related job placement",
            category: C::JobPlacement,
            values: [No, No, No, No, NA, Yes, No, No],
        },
        // ------------------------------------------------ Table 6
        FeatureRow {
            name: "Centralized vs. distributed",
            category: C::SchedulingPerformance,
            values: [
                Text("Cent."), Text("Cent."), Text("Cent."), Text("Cent."),
                Text("Cent."), Text("Cent."), Text("Dist."), Text("Cent."),
            ],
        },
        FeatureRow {
            name: "Scheduler fault tolerance",
            category: C::SchedulingPerformance,
            values: [Yes, No, Yes, Yes, No, Yes, Yes, Yes],
        },
        FeatureRow {
            name: "Scalability and throughput (job slots)",
            category: C::SchedulingPerformance,
            values: [
                Text("10K+"), Text("1K+"), Text("100K+"), Text("10K+"), Text("10K+"),
                Text("100K+"), Text("100K+"), Text("1K+"),
            ],
        },
        // ------------------------------------------------ Table 7
        FeatureRow {
            name: "Prolog/epilog support",
            category: C::JobExecution,
            values: [Yes, No, Yes, Yes, NA, No, Yes, Yes],
        },
        FeatureRow {
            name: "Data movement / file staging",
            category: C::JobExecution,
            values: [Yes, No, Yes, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "Checkpointing",
            category: C::JobExecution,
            values: [Yes, Yes, Yes, Yes, NA, No, No, No],
        },
        FeatureRow {
            name: "Job migration",
            category: C::JobExecution,
            values: [
                Yes, Yes, Yes, Yes, NA, No,
                Partial("user-level"),
                Partial("user-level"),
            ],
        },
        FeatureRow {
            name: "Job restarting",
            category: C::JobExecution,
            values: [Yes, Yes, Yes, Yes, NA, Yes, Yes, Yes],
        },
        FeatureRow {
            name: "Job preemption",
            category: C::JobExecution,
            values: [Yes, Yes, Yes, Yes, NA, No, Yes, Yes],
        },
    ]
}

/// The eight schedulers (paper column order).
pub fn schedulers() -> [SchedulerInfo; 8] {
    SchedulerInfo::all()
}

/// Render one of the paper's Tables 1–7.
pub fn feature_table(category: FeatureCategory) -> Table {
    let mut header = vec!["Feature"];
    let scheds = SchedulerInfo::all();
    for s in &scheds {
        header.push(s.name());
    }
    let mut table = Table::new(category.title(), &header);
    for row in all_features().iter().filter(|r| r.category == category) {
        let mut cells = vec![row.name.to_string()];
        cells.extend(row.values.iter().map(|v| v.render()));
        table.row(&cells);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_category_has_rows() {
        for cat in FeatureCategory::all() {
            let t = feature_table(cat);
            assert!(!t.is_empty(), "{:?} empty", cat);
        }
    }

    #[test]
    fn paper_row_counts() {
        let count = |c: FeatureCategory| {
            all_features().iter().filter(|r| r.category == c).count()
        };
        assert_eq!(count(C::Metadata), 6);
        assert_eq!(count(C::JobTypes), 3);
        assert_eq!(count(C::JobScheduling), 6);
        assert_eq!(count(C::ResourceManagement), 4);
        assert_eq!(count(C::JobPlacement), 8);
        assert_eq!(count(C::SchedulingPerformance), 3);
        assert_eq!(count(C::JobExecution), 6);
    }

    #[test]
    fn key_paper_facts_hold() {
        let rows = all_features();
        let get = |name: &str| rows.iter().find(|r| r.name == name).unwrap();
        // Mesos is the only metascheduler (Table 2).
        let meta = get("Multiple resource managers (metascheduling)");
        let scheds = SchedulerInfo::all();
        for (i, s) in scheds.iter().enumerate() {
            let expect = *s == SchedulerInfo::Mesos;
            assert_eq!(
                meta.values[i].supported(),
                expect,
                "metascheduling for {}",
                s.name()
            );
        }
        // Backfilling is HPC-only (Table 3).
        let bf = get("Backfilling");
        for (i, s) in scheds.iter().enumerate() {
            if s.family() == "Big Data" {
                assert!(!bf.values[i].supported(), "{} backfill", s.name());
            }
        }
        // Only YARN does data-related placement (Table 5).
        let dp = get("Data-related job placement");
        for (i, s) in scheds.iter().enumerate() {
            assert_eq!(dp.values[i].supported(), *s == SchedulerInfo::Yarn);
        }
        // Mesos is the only distributed scheduler (Table 6).
        let cd = get("Centralized vs. distributed");
        for (i, s) in scheds.iter().enumerate() {
            let is_dist = matches!(cd.values[i], FeatureValue::Text("Dist."));
            assert_eq!(is_dist, *s == SchedulerInfo::Mesos);
        }
    }

    #[test]
    fn all_rows_have_eight_columns_and_render() {
        for row in all_features() {
            assert_eq!(row.values.len(), 8);
            for v in &row.values {
                let _ = v.render();
            }
        }
        let t = feature_table(C::Metadata);
        let text = t.render();
        assert!(text.contains("Slurm") && text.contains("Kubernetes"));
    }

    #[test]
    fn table_numbers() {
        assert_eq!(C::Metadata.table_number(), 1);
        assert_eq!(C::JobExecution.table_number(), 7);
    }
}
