//! Scheduler feature-comparison database — the paper's Section 3
//! (Tables 1–7) as queryable data.
//!
//! Eight representative schedulers (LSF, OpenLAVA, Slurm, Grid Engine,
//! Pacora, YARN, Mesos, Kubernetes) × the feature set of §3.2, grouped
//! into the same seven categories the paper tables use.

mod matrix;

pub use matrix::{
    all_features, feature_table, schedulers, FeatureCategory, FeatureValue, SchedulerInfo,
};
