//! `pallas-lint` — static enforcement of the determinism contract.
//!
//! Usage:
//!
//! ```text
//! cargo run --release --bin pallas-lint              # lint this crate
//! cargo run --release --bin pallas-lint -- --json    # machine output
//! cargo run --release --bin pallas-lint -- --root path/to/crate
//! ```
//!
//! Exit codes: 0 clean, 1 diagnostics found, 2 usage/I-O error. The
//! same pass also runs as `tests/lint_clean.rs` (tier-1) and as a
//! dedicated CI step; see the README section "Static analysis & the
//! determinism contract" for the rule table and the
//! `pallas: allow(rule) — reason` suppression grammar.

use std::path::PathBuf;
use std::process::ExitCode;

use sssched::cli::Args;
use sssched::lint;

fn main() -> ExitCode {
    let args = match Args::parse_with_bools(std::env::args().skip(1), &["json"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let root = args
        .opt("root")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));

    // pallas: allow(wall-clock) — linter self-timing for the lint_wall_ms
    // perf metric; no simulated path reads this clock.
    let t0 = std::time::Instant::now();
    let report = match lint::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    if args.flag("json") {
        println!("{}", report.to_json(Some(wall_ms)));
    } else {
        print!("{}", report.render());
        println!("({wall_ms:.1} ms)");
    }
    if report.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
