//! Typed experiment configuration with defaults matching the paper's
//! testbed, loadable from the TOML-subset files in `configs/`.

use super::toml::{parse_toml, TomlValue};
use std::collections::BTreeMap;

/// Which scheduler model to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerChoice {
    /// Slurm-like (new-HPC family).
    Slurm,
    /// Son-of-Grid-Engine-like (traditional HPC family).
    GridEngine,
    /// Mesos-like two-level offer scheduler (open-source big data).
    Mesos,
    /// Hadoop-YARN-like AM-per-job scheduler (open-source big data).
    Yarn,
    /// Sparrow-like decentralized two-choices scheduler (research).
    Sparrow,
    /// Idealized zero-overhead FIFO baseline (testing reference).
    IdealFifo,
}

impl SchedulerChoice {
    /// Parse from a config/CLI string.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "slurm" => Ok(Self::Slurm),
            "gridengine" | "ge" | "sge" => Ok(Self::GridEngine),
            "mesos" => Ok(Self::Mesos),
            "yarn" | "hadoop-yarn" | "hadoopyarn" => Ok(Self::Yarn),
            "sparrow" => Ok(Self::Sparrow),
            "ideal" | "fifo" | "ideal-fifo" => Ok(Self::IdealFifo),
            other => Err(format!("unknown scheduler `{other}`")),
        }
    }

    /// Report name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Slurm => "Slurm",
            Self::GridEngine => "GridEngine",
            Self::Mesos => "Mesos",
            Self::Yarn => "Hadoop YARN",
            Self::Sparrow => "Sparrow",
            Self::IdealFifo => "IdealFIFO",
        }
    }

    /// The paper's four measured schedulers.
    pub fn paper_four() -> [Self; 4] {
        [Self::Slurm, Self::GridEngine, Self::Mesos, Self::Yarn]
    }

    /// Every simulated scheduler family (the `scenarios` experiment's
    /// default set: the paper's four plus the research-family Sparrow
    /// and the zero-overhead reference).
    pub fn all_simulated() -> [Self; 6] {
        [
            Self::Slurm,
            Self::GridEngine,
            Self::Mesos,
            Self::Yarn,
            Self::Sparrow,
            Self::IdealFifo,
        ]
    }
}

/// Experiment configuration (paper defaults).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Compute node count (paper: 44).
    pub nodes: u32,
    /// Cores per node (paper: 32).
    pub cores_per_node: u32,
    /// Node memory (MB).
    pub mem_mb: u64,
    /// Trials per task set (paper: 3).
    pub trials: u32,
    /// Root RNG seed.
    pub seed: u64,
    /// Schedulers to benchmark.
    pub schedulers: Vec<SchedulerChoice>,
    /// Tasks-per-processor sweep for Figure 4/6 (the paper sweeps n
    /// across the Table 9 values plus intermediate points).
    pub n_sweep: Vec<u32>,
    /// Output directory for CSV/trace artifacts.
    pub out_dir: String,
    /// If set, scales the cluster down by this integer factor (every
    /// experiment stays shape-faithful since n per processor is what
    /// matters; used by quick CI runs).
    pub scale_down: u32,
    /// Worker threads for sweep execution (`--jobs`). Defaults to
    /// `std::thread::available_parallelism()`; results are bit-identical
    /// for every value (see `harness::parallel`).
    pub jobs: u32,
    /// Tasks per processor for the `scenarios` experiment (each
    /// scenario workload carries `scenario_n × P` tasks of
    /// `240 / scenario_n` seconds, the Table 9 per-processor work).
    pub scenario_n: u32,
    /// Offered load ρ for the `scenarios` Poisson-arrival workload
    /// (arrival rate = ρ·P / task time).
    pub arrival_rho: f64,
    /// Checkpoint-cost sweep for the `preempt` experiment, as fractions
    /// of the task time t (0.0 = free eviction).
    pub preempt_cost_fracs: Vec<f64>,
    /// Fraction of `preempt`-experiment tasks that are high-priority
    /// foreground arrivals (the rest is preemptible background).
    pub preempt_hi_frac: f64,
    /// Service-footprint sweep for the `service` experiment: fractions
    /// of the cluster's cores pinned by long-running service tasks.
    pub service_fracs: Vec<f64>,
    /// Observation window (virtual s) of the `service` experiment's
    /// horizon-bounded runs.
    pub service_horizon: f64,
    /// MTBF sweep for the `churn` experiment, as fractions of the
    /// observation window: each node draws exponential failures with
    /// mean `frac × window` (smaller = harsher churn).
    pub churn_mtbf_fracs: Vec<f64>,
    /// Mean time-to-repair of the `churn` experiment, as a fraction of
    /// the observation window.
    pub churn_mttr_frac: f64,
    /// Failure-detection timeouts (virtual s) swept by the `degraded`
    /// experiment; heartbeats run at half each timeout.
    pub degraded_detect_timeouts: Vec<f64>,
    /// Control-message loss probabilities of the `degraded` severity
    /// levels, in non-decreasing order (duplication runs at half the
    /// loss probability).
    pub degraded_loss_probs: Vec<f64>,
    /// Mean control-message latencies (virtual s) of the `degraded`
    /// severity levels; zipped 1:1 with `degraded_loss_probs` and also
    /// non-decreasing, so severity is totally ordered.
    pub degraded_latency_means: Vec<f64>,
    /// Speculative re-execution threshold of the `degraded`
    /// experiment's spec-armed rows: duplicate a task once it runs
    /// longer than this multiple of its class's streaming mean.
    pub degraded_speculate_factor: f64,
    /// Total-task-count sweep of the `scale` experiment (decade steps
    /// through the 10⁴–10⁶ short-job regime of Byun et al.).
    pub scale_ns: Vec<u32>,
    /// Cluster core counts of the `scale` experiment; each must be a
    /// positive multiple of `harness::SCALE_CORES_PER_NODE` (25).
    pub scale_procs: Vec<u32>,
    /// Extend `scale_ns` with a 10⁷-task point (`--huge`). Off by
    /// default — the point takes minutes and is for dedicated perf
    /// sessions, not CI.
    pub scale_huge: bool,
    /// Tasks-per-processor sweep of the `model` experiment's fit phase.
    pub model_ns: Vec<u32>,
    /// Target predicted utilization the `model` experiment's auto-tuner
    /// inverts the analytic model for (the paper's headline: ≥ 90 % for
    /// short tasks).
    pub model_target_util: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            nodes: 44,
            cores_per_node: 32,
            mem_mb: 64 * 1024,
            trials: 3,
            seed: 0x55C4ED,
            schedulers: SchedulerChoice::paper_four().to_vec(),
            n_sweep: vec![4, 8, 16, 32, 48, 96, 240],
            out_dir: "out".into(),
            scale_down: 1,
            jobs: crate::harness::default_jobs() as u32,
            scenario_n: 8,
            arrival_rho: 0.7,
            preempt_cost_fracs: vec![0.0, 0.25],
            preempt_hi_frac: 0.25,
            service_fracs: vec![0.25, 0.5],
            service_horizon: 240.0,
            churn_mtbf_fracs: vec![4.0, 1.0, 0.25],
            churn_mttr_frac: 0.05,
            degraded_detect_timeouts: vec![1.0, 8.0],
            degraded_loss_probs: vec![0.0, 0.05, 0.2],
            degraded_latency_means: vec![0.0, 1.0, 4.0],
            degraded_speculate_factor: 3.0,
            scale_ns: vec![1_000, 10_000, 100_000, 1_000_000],
            scale_procs: vec![1_000, 10_000],
            scale_huge: false,
            model_ns: vec![4, 8, 16, 32, 48, 96, 240],
            model_target_util: 0.9,
        }
    }
}

impl ExperimentConfig {
    /// Effective processor count.
    pub fn processors(&self) -> u64 {
        (self.nodes as u64 * self.cores_per_node as u64) / self.scale_down.max(1) as u64
    }

    /// Effective node count after scale-down.
    pub fn effective_nodes(&self) -> u32 {
        (self.nodes / self.scale_down.max(1)).max(1)
    }

    /// Sweep worker-thread count (≥ 1).
    pub fn effective_jobs(&self) -> usize {
        self.jobs.max(1) as usize
    }

    /// Load from a parsed TOML map (unknown keys rejected to catch typos).
    pub fn from_map(map: &BTreeMap<String, TomlValue>) -> Result<Self, String> {
        let mut cfg = Self::default();
        for (key, value) in map {
            match key.as_str() {
                "cluster.nodes" => cfg.nodes = get_u32(value, key)?,
                "cluster.cores_per_node" => cfg.cores_per_node = get_u32(value, key)?,
                "cluster.mem_mb" => cfg.mem_mb = get_u32(value, key)? as u64,
                "experiment.trials" => cfg.trials = get_u32(value, key)?,
                "experiment.seed" => {
                    cfg.seed = value.as_i64().ok_or_else(|| bad(key))? as u64
                }
                "experiment.scale_down" => cfg.scale_down = get_u32(value, key)?,
                "experiment.jobs" => cfg.jobs = get_u32(value, key)?,
                "experiment.scenario_n" => cfg.scenario_n = get_u32(value, key)?,
                "experiment.arrival_rho" => {
                    cfg.arrival_rho = value.as_f64().ok_or_else(|| bad(key))?
                }
                "experiment.preempt_hi_frac" => {
                    cfg.preempt_hi_frac = value.as_f64().ok_or_else(|| bad(key))?
                }
                "experiment.preempt_cost_fracs" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.preempt_cost_fracs = arr
                        .iter()
                        .map(|v| v.as_f64().ok_or_else(|| bad(key)))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.service_fracs" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.service_fracs = arr
                        .iter()
                        .map(|v| v.as_f64().ok_or_else(|| bad(key)))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.service_horizon" => {
                    cfg.service_horizon = value.as_f64().ok_or_else(|| bad(key))?
                }
                "experiment.churn_mtbf_fracs" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.churn_mtbf_fracs = arr
                        .iter()
                        .map(|v| v.as_f64().ok_or_else(|| bad(key)))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.churn_mttr_frac" => {
                    cfg.churn_mttr_frac = value.as_f64().ok_or_else(|| bad(key))?
                }
                "experiment.degraded_detect_timeouts" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.degraded_detect_timeouts = arr
                        .iter()
                        .map(|v| v.as_f64().ok_or_else(|| bad(key)))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.degraded_loss_probs" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.degraded_loss_probs = arr
                        .iter()
                        .map(|v| v.as_f64().ok_or_else(|| bad(key)))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.degraded_latency_means" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.degraded_latency_means = arr
                        .iter()
                        .map(|v| v.as_f64().ok_or_else(|| bad(key)))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.degraded_speculate_factor" => {
                    cfg.degraded_speculate_factor = value.as_f64().ok_or_else(|| bad(key))?
                }
                "experiment.scale_ns" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    // Range-checked (not `as`-cast) so a negative value
                    // is rejected instead of wrapping to a huge count.
                    cfg.scale_ns = arr
                        .iter()
                        .map(|v| get_u32(v, key))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.scale_huge" => {
                    cfg.scale_huge = value.as_bool().ok_or_else(|| bad(key))?
                }
                "experiment.scale_procs" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.scale_procs = arr
                        .iter()
                        .map(|v| get_u32(v, key))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.model_ns" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.model_ns = arr
                        .iter()
                        .map(|v| get_u32(v, key))
                        .collect::<Result<_, _>>()?;
                }
                "experiment.model_target_util" => {
                    cfg.model_target_util = value.as_f64().ok_or_else(|| bad(key))?
                }
                "experiment.out_dir" => {
                    cfg.out_dir = value.as_str().ok_or_else(|| bad(key))?.to_string()
                }
                "experiment.schedulers" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.schedulers = arr
                        .iter()
                        .map(|v| {
                            v.as_str()
                                .ok_or_else(|| bad(key))
                                .and_then(SchedulerChoice::parse)
                        })
                        .collect::<Result<_, _>>()?;
                }
                "experiment.n_sweep" => {
                    let arr = match value {
                        TomlValue::Array(xs) => xs,
                        _ => return Err(bad(key)),
                    };
                    cfg.n_sweep = arr
                        .iter()
                        .map(|v| v.as_i64().map(|i| i as u32).ok_or_else(|| bad(key)))
                        .collect::<Result<_, _>>()?;
                }
                other => return Err(format!("unknown config key `{other}`")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        Self::from_map(&parse_toml(text)?)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        Self::from_toml(&text)
    }

    /// Sanity checks.
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes == 0 || self.cores_per_node == 0 {
            return Err("cluster must have nodes and cores".into());
        }
        if self.trials == 0 {
            return Err("trials must be >= 1".into());
        }
        if self.schedulers.is_empty() {
            return Err("at least one scheduler required".into());
        }
        if self.n_sweep.is_empty() || self.n_sweep.iter().any(|&n| n == 0) {
            return Err("n_sweep must be non-empty, positive".into());
        }
        if self.jobs == 0 {
            return Err("jobs must be >= 1".into());
        }
        if self.scenario_n == 0 {
            return Err("scenario_n must be >= 1".into());
        }
        if !(self.arrival_rho.is_finite() && self.arrival_rho > 0.0 && self.arrival_rho < 1.0) {
            return Err("arrival_rho must be in (0, 1)".into());
        }
        if self.preempt_cost_fracs.is_empty()
            || self
                .preempt_cost_fracs
                .iter()
                .any(|&f| !f.is_finite() || f < 0.0)
        {
            return Err("preempt_cost_fracs must be non-empty, finite, >= 0".into());
        }
        if !(self.preempt_hi_frac.is_finite()
            && self.preempt_hi_frac > 0.0
            && self.preempt_hi_frac < 1.0)
        {
            return Err("preempt_hi_frac must be in (0, 1)".into());
        }
        if self.service_fracs.is_empty()
            || self
                .service_fracs
                .iter()
                .any(|&f| !f.is_finite() || !(0.0..1.0).contains(&f))
        {
            return Err("service_fracs must be non-empty, finite, in [0, 1)".into());
        }
        if !(self.service_horizon.is_finite() && self.service_horizon > 0.0) {
            return Err("service_horizon must be finite and > 0".into());
        }
        if self.churn_mtbf_fracs.is_empty()
            || self
                .churn_mtbf_fracs
                .iter()
                .any(|&f| !f.is_finite() || f <= 0.0)
        {
            return Err("churn_mtbf_fracs must be non-empty, finite, > 0".into());
        }
        if !(self.churn_mttr_frac.is_finite() && self.churn_mttr_frac > 0.0) {
            return Err("churn_mttr_frac must be finite and > 0".into());
        }
        if self.degraded_detect_timeouts.is_empty()
            || self
                .degraded_detect_timeouts
                .iter()
                .any(|&t| !t.is_finite() || t <= 0.0)
        {
            return Err("degraded_detect_timeouts must be non-empty, finite, > 0".into());
        }
        if self.degraded_loss_probs.is_empty()
            || self
                .degraded_loss_probs
                .iter()
                .any(|&p| !p.is_finite() || !(0.0..1.0).contains(&p))
        {
            return Err("degraded_loss_probs must be non-empty, finite, in [0, 1)".into());
        }
        if self.degraded_latency_means.len() != self.degraded_loss_probs.len()
            || self
                .degraded_latency_means
                .iter()
                .any(|&l| !l.is_finite() || l < 0.0)
        {
            return Err(
                "degraded_latency_means must be finite, >= 0, and zip 1:1 with \
                 degraded_loss_probs"
                    .into(),
            );
        }
        // Severity must be totally ordered so "goodput monotone
        // non-increasing in severity" is a meaningful gate.
        if self.degraded_loss_probs.windows(2).any(|w| w[1] < w[0])
            || self.degraded_latency_means.windows(2).any(|w| w[1] < w[0])
        {
            return Err(
                "degraded severity levels must be non-decreasing in both loss \
                 probability and latency mean"
                    .into(),
            );
        }
        if !(self.degraded_speculate_factor.is_finite() && self.degraded_speculate_factor > 1.0) {
            return Err("degraded_speculate_factor must be finite and > 1".into());
        }
        if self.scale_ns.is_empty() || self.scale_ns.iter().any(|&n| n == 0) {
            return Err("scale_ns must be non-empty, positive".into());
        }
        let cpn = crate::harness::SCALE_CORES_PER_NODE;
        if self.scale_procs.is_empty()
            || self.scale_procs.iter().any(|&p| p == 0 || p % cpn != 0)
        {
            return Err(format!(
                "scale_procs must be non-empty, positive multiples of {cpn}"
            ));
        }
        if self.model_ns.is_empty() || self.model_ns.iter().any(|&n| n == 0) {
            return Err("model_ns must be non-empty, positive".into());
        }
        if !(self.model_target_util.is_finite()
            && self.model_target_util > 0.0
            && self.model_target_util < 1.0)
        {
            return Err("model_target_util must be in (0, 1)".into());
        }
        Ok(())
    }
}

/// Canonical registry of `experiment` subcommand names. This is the
/// single source of truth the CLI dispatches from (`experiment all`
/// iterates it) and that `pallas-lint`'s `experiment-wiring` rule
/// cross-checks against `main.rs` dispatch/validate arms and the
/// README EXPERIMENTS table — adding a name here without wiring it
/// everywhere fails the linter.
pub const EXPERIMENT_NAMES: &[&str] = &[
    "table9",
    "table10",
    "fig4",
    "fig5",
    "fig6",
    "fig7",
    "scenarios",
    "preempt",
    "service",
    "churn",
    "degraded",
    "scale",
    "model",
];

/// Validate a CLI experiment name against [`EXPERIMENT_NAMES`]
/// (`all` is the meta-name that runs the whole registry).
pub fn validate_experiment(name: &str) -> Result<(), String> {
    if name == "all" || EXPERIMENT_NAMES.contains(&name) {
        Ok(())
    } else {
        Err(format!(
            "unknown experiment `{name}` (known: {}, all)",
            EXPERIMENT_NAMES.join(", ")
        ))
    }
}

fn get_u32(v: &TomlValue, key: &str) -> Result<u32, String> {
    v.as_i64()
        .filter(|&i| i >= 0 && i <= u32::MAX as i64)
        .map(|i| i as u32)
        .ok_or_else(|| bad(key))
}

fn bad(key: &str) -> String {
    format!("invalid value for `{key}`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.processors(), 1408);
        assert_eq!(c.trials, 3);
        assert_eq!(c.schedulers.len(), 4);
    }

    #[test]
    fn from_toml_roundtrip() {
        let c = ExperimentConfig::from_toml(
            r#"
[cluster]
nodes = 8
cores_per_node = 4
[experiment]
trials = 2
schedulers = ["slurm", "mesos"]
n_sweep = [4, 240]
"#,
        )
        .unwrap();
        assert_eq!(c.processors(), 32);
        assert_eq!(c.trials, 2);
        assert_eq!(
            c.schedulers,
            vec![SchedulerChoice::Slurm, SchedulerChoice::Mesos]
        );
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(ExperimentConfig::from_toml("whoops = 1").is_err());
    }

    #[test]
    fn rejects_invalid() {
        assert!(ExperimentConfig::from_toml("[experiment]\ntrials = 0").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nschedulers = [\"bogus\"]").is_err());
    }

    #[test]
    fn jobs_parse_and_validate() {
        let c = ExperimentConfig::from_toml("[experiment]\njobs = 4").unwrap();
        assert_eq!(c.jobs, 4);
        assert_eq!(c.effective_jobs(), 4);
        assert!(ExperimentConfig::from_toml("[experiment]\njobs = 0").is_err());
        assert!(ExperimentConfig::default().effective_jobs() >= 1);
    }

    #[test]
    fn scale_down() {
        let mut c = ExperimentConfig::default();
        c.scale_down = 4;
        assert_eq!(c.processors(), 352);
        assert_eq!(c.effective_nodes(), 11);
    }

    #[test]
    fn scheduler_parse_aliases() {
        assert_eq!(
            SchedulerChoice::parse("GE").unwrap(),
            SchedulerChoice::GridEngine
        );
        assert_eq!(SchedulerChoice::parse("YARN").unwrap(), SchedulerChoice::Yarn);
        assert_eq!(
            SchedulerChoice::parse("Sparrow").unwrap(),
            SchedulerChoice::Sparrow
        );
        assert!(SchedulerChoice::parse("pbs").is_err());
    }

    #[test]
    fn preempt_keys_parse_and_validate() {
        let c = ExperimentConfig::from_toml(
            "[experiment]\npreempt_hi_frac = 0.4\npreempt_cost_fracs = [0.0, 0.5, 2.0]",
        )
        .unwrap();
        assert!((c.preempt_hi_frac - 0.4).abs() < 1e-12);
        assert_eq!(c.preempt_cost_fracs, vec![0.0, 0.5, 2.0]);
        assert!(
            ExperimentConfig::from_toml("[experiment]\npreempt_hi_frac = 1.5").is_err()
        );
        assert!(
            ExperimentConfig::from_toml("[experiment]\npreempt_cost_fracs = [-1.0]").is_err()
        );
    }

    #[test]
    fn service_keys_parse_and_validate() {
        let c = ExperimentConfig::from_toml(
            "[experiment]\nservice_fracs = [0.1, 0.6]\nservice_horizon = 120.0",
        )
        .unwrap();
        assert_eq!(c.service_fracs, vec![0.1, 0.6]);
        assert!((c.service_horizon - 120.0).abs() < 1e-12);
        assert!(ExperimentConfig::from_toml("[experiment]\nservice_fracs = [1.5]").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nservice_fracs = []").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nservice_horizon = 0").is_err());
    }

    #[test]
    fn churn_keys_parse_and_validate() {
        let c = ExperimentConfig::from_toml(
            "[experiment]\nchurn_mtbf_fracs = [2.0, 0.5]\nchurn_mttr_frac = 0.1",
        )
        .unwrap();
        assert_eq!(c.churn_mtbf_fracs, vec![2.0, 0.5]);
        assert!((c.churn_mttr_frac - 0.1).abs() < 1e-12);
        assert!(ExperimentConfig::from_toml("[experiment]\nchurn_mtbf_fracs = []").is_err());
        assert!(
            ExperimentConfig::from_toml("[experiment]\nchurn_mtbf_fracs = [0.0]").is_err()
        );
        assert!(ExperimentConfig::from_toml("[experiment]\nchurn_mttr_frac = 0").is_err());
    }

    #[test]
    fn degraded_keys_parse_and_validate() {
        let c = ExperimentConfig::from_toml(
            "[experiment]\ndegraded_detect_timeouts = [2.0]\n\
             degraded_loss_probs = [0.0, 0.1]\n\
             degraded_latency_means = [0.5, 1.5]\n\
             degraded_speculate_factor = 2.5",
        )
        .unwrap();
        assert_eq!(c.degraded_detect_timeouts, vec![2.0]);
        assert_eq!(c.degraded_loss_probs, vec![0.0, 0.1]);
        assert_eq!(c.degraded_latency_means, vec![0.5, 1.5]);
        assert!((c.degraded_speculate_factor - 2.5).abs() < 1e-12);
        assert!(
            ExperimentConfig::from_toml("[experiment]\ndegraded_detect_timeouts = []").is_err()
        );
        assert!(
            ExperimentConfig::from_toml("[experiment]\ndegraded_detect_timeouts = [0.0]")
                .is_err()
        );
        // Loss of exactly 1.0 would retry forever; the builder rejects
        // it and so must the config.
        assert!(
            ExperimentConfig::from_toml("[experiment]\ndegraded_loss_probs = [1.0]").is_err()
        );
        // The level vectors must zip 1:1 ...
        assert!(ExperimentConfig::from_toml(
            "[experiment]\ndegraded_loss_probs = [0.1]\n\
             degraded_latency_means = [1.0, 2.0]"
        )
        .is_err());
        // ... and severity must be totally ordered.
        assert!(ExperimentConfig::from_toml(
            "[experiment]\ndegraded_loss_probs = [0.2, 0.1]\n\
             degraded_latency_means = [0.0, 1.0]"
        )
        .is_err());
        assert!(
            ExperimentConfig::from_toml("[experiment]\ndegraded_speculate_factor = 1.0")
                .is_err()
        );
    }

    #[test]
    fn scale_keys_parse_and_validate() {
        let c = ExperimentConfig::from_toml(
            "[experiment]\nscale_ns = [500, 2000]\nscale_procs = [100]",
        )
        .unwrap();
        assert_eq!(c.scale_ns, vec![500, 2000]);
        assert_eq!(c.scale_procs, vec![100]);
        assert!(!c.scale_huge);
        let h = ExperimentConfig::from_toml("[experiment]\nscale_huge = true").unwrap();
        assert!(h.scale_huge);
        assert!(ExperimentConfig::from_toml("[experiment]\nscale_huge = 3").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nscale_ns = []").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nscale_procs = [0]").is_err());
        // Negative values must be rejected, not wrapped to huge u32s.
        assert!(ExperimentConfig::from_toml("[experiment]\nscale_ns = [-1]").is_err());
        // Non-multiple of the scale cluster's cores-per-node.
        assert!(ExperimentConfig::from_toml("[experiment]\nscale_procs = [1001]").is_err());
    }

    #[test]
    fn model_keys_parse_and_validate() {
        let c = ExperimentConfig::from_toml(
            "[experiment]\nmodel_ns = [4, 48]\nmodel_target_util = 0.8",
        )
        .unwrap();
        assert_eq!(c.model_ns, vec![4, 48]);
        assert!((c.model_target_util - 0.8).abs() < 1e-12);
        assert!(ExperimentConfig::from_toml("[experiment]\nmodel_ns = []").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nmodel_ns = [0]").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nmodel_ns = [-4]").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nmodel_target_util = 1.0").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nmodel_target_util = 0").is_err());
    }

    #[test]
    fn scenario_keys_parse_and_validate() {
        let c = ExperimentConfig::from_toml(
            "[experiment]\nscenario_n = 16\narrival_rho = 0.5",
        )
        .unwrap();
        assert_eq!(c.scenario_n, 16);
        assert!((c.arrival_rho - 0.5).abs() < 1e-12);
        assert!(ExperimentConfig::from_toml("[experiment]\nscenario_n = 0").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\narrival_rho = 1.5").is_err());
    }

    #[test]
    fn experiment_registry_validates_names() {
        for name in EXPERIMENT_NAMES {
            validate_experiment(name).unwrap();
        }
        validate_experiment("all").unwrap();
        let err = validate_experiment("tabel9").unwrap_err();
        assert!(err.contains("unknown experiment `tabel9`"));
        assert!(err.contains("table9"), "error lists the known names");
        // The registry is duplicate-free — a duplicate would make the
        // `experiment all` loop run something twice.
        let mut sorted: Vec<&str> = EXPERIMENT_NAMES.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), EXPERIMENT_NAMES.len());
    }
}
