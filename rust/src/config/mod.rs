//! Configuration system.
//!
//! `toml.rs` is a minimal TOML-subset parser (tables, string / float /
//! integer / bool values, comments) — `serde`/`toml` crates are not in
//! the offline crate set. `schema.rs` maps parsed values onto typed
//! experiment configuration with defaults and validation.

mod schema;
mod toml;

pub use schema::{validate_experiment, ExperimentConfig, SchedulerChoice, EXPERIMENT_NAMES};
pub use toml::{parse_toml, TomlValue};
