//! Minimal TOML-subset parser.
//!
//! Supports: `[table]` and `[table.sub]` headers, `key = value` pairs
//! with string, integer, float, boolean and flat-array values, `#`
//! comments, and blank lines. Keys are flattened to dotted paths
//! (`table.sub.key`). This covers everything sssched config files use.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Flat array of values.
    Array(Vec<TomlValue>),
}

impl TomlValue {
    /// As f64 (ints coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

fn parse_scalar(raw: &str, lineno: usize) -> Result<TomlValue, String> {
    let raw = raw.trim();
    if raw.starts_with('"') {
        if !raw.ends_with('"') || raw.len() < 2 {
            return Err(format!("line {lineno}: unterminated string"));
        }
        let inner = &raw[1..raw.len() - 1];
        // Basic escapes.
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("line {lineno}: bad escape {other:?}")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if raw == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if raw == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if raw.starts_with('[') {
        if !raw.ends_with(']') {
            return Err(format!("line {lineno}: unterminated array"));
        }
        let inner = &raw[1..raw.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                if part.trim().is_empty() {
                    continue; // trailing comma
                }
                items.push(parse_scalar(part, lineno)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = raw.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = raw.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("line {lineno}: cannot parse value `{raw}`"))
}

/// Parse TOML-subset text into a flat dotted-key map.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>, String> {
    let mut out = BTreeMap::new();
    let mut prefix = String::new();
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        // Strip comments outside strings (simple heuristic: TOML-subset
        // forbids '#' inside our strings' values on the same line unless quoted).
        let line = strip_comment(line);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {lineno}: malformed table header"));
            }
            prefix = line[1..line.len() - 1].trim().to_string();
            if prefix.is_empty() {
                return Err(format!("line {lineno}: empty table name"));
            }
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {lineno}: expected key = value"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {lineno}: empty key"));
        }
        let value = parse_scalar(&line[eq + 1..], lineno)?;
        let full = if prefix.is_empty() {
            key.to_string()
        } else {
            format!("{prefix}.{key}")
        };
        if out.insert(full.clone(), value).is_some() {
            return Err(format!("line {lineno}: duplicate key `{full}`"));
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_types() {
        let text = r#"
# experiment config
name = "table9"   # trailing comment
trials = 3
[cluster]
nodes = 44
cores = 32
rpc_latency = 2.0e-4
isolated = true
[sched.slurm]
dispatch_ms = 6.5
ns = [4, 8, 48, 240]
"#;
        let m = parse_toml(text).unwrap();
        assert_eq!(m["name"].as_str(), Some("table9"));
        assert_eq!(m["trials"].as_i64(), Some(3));
        assert_eq!(m["cluster.nodes"].as_i64(), Some(44));
        assert_eq!(m["cluster.rpc_latency"].as_f64(), Some(2.0e-4));
        assert_eq!(m["cluster.isolated"].as_bool(), Some(true));
        assert_eq!(m["sched.slurm.dispatch_ms"].as_f64(), Some(6.5));
        match &m["sched.slurm.ns"] {
            TomlValue::Array(xs) => assert_eq!(xs.len(), 4),
            other => panic!("not an array: {other:?}"),
        }
    }

    #[test]
    fn string_escapes() {
        let m = parse_toml(r#"s = "a\nb \"q\" c""#).unwrap();
        assert_eq!(m["s"].as_str(), Some("a\nb \"q\" c"));
    }

    #[test]
    fn hash_inside_string_kept() {
        let m = parse_toml(r##"s = "a#b" # comment"##).unwrap();
        assert_eq!(m["s"].as_str(), Some("a#b"));
    }

    #[test]
    fn underscored_numbers() {
        let m = parse_toml("n = 337_920").unwrap();
        assert_eq!(m["n"].as_i64(), Some(337920));
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(parse_toml("a = 1\na = 2").is_err());
        assert!(parse_toml("nonsense").is_err());
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("x = @@").is_err());
    }

    #[test]
    fn int_vs_float() {
        let m = parse_toml("i = 3\nf = 3.0").unwrap();
        assert_eq!(m["i"], TomlValue::Int(3));
        assert_eq!(m["f"], TomlValue::Float(3.0));
        assert_eq!(m["i"].as_f64(), Some(3.0));
    }
}
