//! Discrete-event simulation core.
//!
//! The paper's measurements ran on a real 1408-core cluster; here the
//! cluster and the scheduler control plane are simulated in virtual time
//! (see DESIGN.md §2 for why the substitution preserves the measured
//! behaviour). This module provides the generic machinery: a
//! deterministic event queue, a virtual clock and serial service
//! stations (the scheduler daemon is one).

mod engine;

pub use engine::{EventQueue, MultiServer, ServiceStation, Time};
