//! Discrete-event simulation core.
//!
//! The paper's measurements ran on a real 1408-core cluster; here the
//! cluster and the scheduler control plane are simulated in virtual time
//! (see DESIGN.md §2 for why the substitution preserves the measured
//! behaviour). This module provides the generic machinery: a
//! deterministic event queue, a virtual clock, serial service stations
//! (the scheduler daemon is one), and [`SimScratch`] — the reusable
//! buffer set that makes repeated trials allocation-free.

mod engine;
mod kernel;
mod pending;
mod scratch;

pub use engine::{EventQueue, MultiServer, ServiceStation, SimEv, Time};
pub use kernel::{Kernel, KernelCtx, Launch, LaunchFn, SchedPolicy};
pub use pending::{OrderIndex, OrderMode, PendingList};
pub use scratch::SimScratch;
