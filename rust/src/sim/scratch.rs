//! Reusable per-worker simulation buffers — the zero-allocation core.
//!
//! Every `Scheduler::run` in the seed allocated its event-queue heap,
//! pending queue, slot pool, trace buffers and per-slot memory table
//! from scratch, once per trial. A sweep runs hundreds of trials, so
//! the allocator churn (and the cold pages behind it) sat directly on
//! the hot path. [`SimScratch`] owns all of those buffers; a worker
//! thread creates one and threads it through
//! [`crate::sched::Scheduler::run_with_scratch`] for every cell it
//! executes, so repeated trials reuse warm, already-sized allocations.
//!
//! Since the kernel refactor (see [`crate::sim::Kernel`]) the scratch
//! also carries the dependency, gang and multi-core tables; they are
//! sized lazily per run, so plain array workloads never touch them.
//!
//! Correctness contract: [`SimScratch::begin`] rewinds every buffer to
//! the state a fresh allocation would have, so a run through a reused
//! scratch is bit-identical to a run through a new one. The
//! `parallel_determinism` integration test pins this down.

use super::engine::{EventQueue, SimEv};
use super::pending::{OrderIndex, PendingList};
use crate::cluster::{ClusterSpec, SlotPool};
use crate::util::stats::{P2Quantile, Reservoir, WAIT_SAMPLE_CAP};
use crate::workload::{JobKind, TaskSpec, TraceRecord};

/// Struct-of-arrays mirror of the per-task spec fields the kernel's
/// event loop actually touches, indexed by dense task id.
///
/// `TaskSpec` is ~100 bytes plus a `deps` vector; the hot loop
/// (dispatch, start, end, requeue) reads only these six scalars, so
/// walking the array-of-structs form wastes most of every cache line
/// and ~3× the bandwidth. The columns below pack the hot fields at
/// their natural widths (`kind` as one byte, not an enum-in-a-struct)
/// so a million-task run streams through them cache-linearly. Cold
/// paths (eviction specs, fault retries, ordering keys) keep reading
/// the original `&[TaskSpec]` — the SoA is a performance mirror, not a
/// second source of truth, and is filled in the kernel's existing
/// one-pass workload scan.
#[derive(Default)]
pub struct TaskSoa {
    /// Productive seconds per task.
    pub duration: Vec<f64>,
    /// Submission time per task.
    pub submit_at: Vec<f64>,
    /// Core slots required.
    pub cores: Vec<u32>,
    /// Resident memory demanded from the primary slot's node (MB).
    pub mem_mb: Vec<i64>,
    /// Owning job id.
    pub job: Vec<u32>,
    /// [`JobKind`] packed to one byte ([`Self::KIND_ARRAY`]…).
    pub kind: Vec<u8>,
}

impl TaskSoa {
    /// `kind` byte for [`JobKind::Array`].
    pub const KIND_ARRAY: u8 = 0;
    /// `kind` byte for [`JobKind::Parallel`].
    pub const KIND_PARALLEL: u8 = 1;
    /// `kind` byte for [`JobKind::Service`].
    pub const KIND_SERVICE: u8 = 2;

    /// Pack a [`JobKind`] into its column byte.
    pub fn kind_byte(kind: JobKind) -> u8 {
        match kind {
            JobKind::Array => Self::KIND_ARRAY,
            JobKind::Parallel => Self::KIND_PARALLEL,
            JobKind::Service => Self::KIND_SERVICE,
        }
    }

    /// Drop all rows (capacity retained for the warm path).
    pub fn clear(&mut self) {
        self.duration.clear();
        self.submit_at.clear();
        self.cores.clear();
        self.mem_mb.clear();
        self.job.clear();
        self.kind.clear();
    }

    /// Reserve for `n` rows ahead of a fill pass.
    pub fn reserve(&mut self, n: usize) {
        self.duration.reserve(n);
        self.submit_at.reserve(n);
        self.cores.reserve(n);
        self.mem_mb.reserve(n);
        self.job.reserve(n);
        self.kind.reserve(n);
    }

    /// Append one task's hot fields (called once per task, in dense id
    /// order, by the kernel's workload scan).
    #[inline]
    pub fn push(&mut self, t: &TaskSpec) {
        self.duration.push(t.duration);
        self.submit_at.push(t.submit_at);
        self.cores.push(t.cores);
        self.mem_mb.push(t.mem_mb);
        self.job.push(t.job);
        self.kind.push(Self::kind_byte(t.kind));
    }

    /// Rows filled.
    pub fn len(&self) -> usize {
        self.duration.len()
    }

    /// Whether the table is empty.
    pub fn is_empty(&self) -> bool {
        self.duration.is_empty()
    }

    /// Whether task `id` is a service task.
    #[inline]
    pub fn is_service(&self, id: u32) -> bool {
        self.kind[id as usize] == Self::KIND_SERVICE
    }

    /// Whether task `id` belongs to a parallel (gang) job.
    #[inline]
    pub fn is_parallel(&self, id: u32) -> bool {
        self.kind[id as usize] == Self::KIND_PARALLEL
    }
}

/// Warm buffers for one simulation worker.
pub struct SimScratch {
    /// Shared event queue (all simulators use the [`SimEv`] payload).
    pub queue: EventQueue<SimEv>,
    /// Pending-task queue (task ids), dependency-gated by the kernel:
    /// an intrusive linked list with O(1) membership/removal (FIFO
    /// iteration order matches the historical `VecDeque`).
    pub pending: PendingList,
    /// Incremental ordered ready-queue for the `Ordered`/`Preemptive`
    /// combinators (inactive for plain runs).
    pub order: OrderIndex,
    /// Core-slot pool, rebuilt in place per run via [`SlotPool::reinit`].
    pub pool: SlotPool,
    /// Memory (MB) held by each slot's current task.
    pub slot_mem: Vec<i64>,
    /// Per-task trace records (only filled when the run collects traces).
    pub trace: Vec<TraceRecord>,
    /// task id -> index into `trace` (`u32::MAX` = not yet started).
    pub trace_idx: Vec<u32>,
    /// Per-slot busy-until times (Sparrow's worker backlogs).
    pub busy_until: Vec<f64>,
    /// Unmet-dependency count per task (DAG workloads only).
    pub indeg: Vec<u32>,
    /// CSR offsets of the dep -> dependents edge list.
    pub dep_off: Vec<u32>,
    /// CSR edges: dependents of each task, grouped by dependency.
    pub dep_edges: Vec<u32>,
    /// Whether each task's submission has reached the control plane
    /// (DAG workloads only; gates admission of late-ready children).
    pub submitted: Vec<bool>,
    /// Parallel-job member counts by job id (gang workloads only).
    pub gang_total: Vec<u32>,
    /// Parallel-job members currently pending, by job id.
    pub gang_ready: Vec<u32>,
    /// Per-task (start, len) span into `extra_slots` (multi-core only).
    pub extra_span: Vec<(u32, u32)>,
    /// Arena of extra (non-primary) slots held by multi-core tasks.
    pub extra_slots: Vec<u32>,
    /// Remaining productive seconds per task (preemption only; progress
    /// preserved across evictions).
    pub remaining: Vec<f64>,
    /// Start time of each task's current execution span (`NAN` when the
    /// task is not running; preemption only).
    pub span_start: Vec<f64>,
    /// Primary slot of each task's current run (`u32::MAX` when not
    /// running; preemption only).
    pub run_slot: Vec<u32>,
    /// Per-task dispatch epoch, bumped on start/resume/evict to
    /// invalidate in-flight `End` events (preemption only).
    pub epoch: Vec<u32>,
    /// Per-task eviction count (preemption only).
    pub evictions: Vec<u32>,
    /// Whether a task's current run holds kernel-pool slots (false for
    /// policies doing their own capacity bookkeeping, e.g. Sparrow;
    /// preemption only).
    pub kernel_alloc: Vec<bool>,
    /// Running-preemptible registry: task ids currently evictable
    /// (preemption only; mirrors the legacy full-task scan in O(R)).
    pub rp_list: Vec<u32>,
    /// task id -> index into `rp_list` (`u32::MAX` = unregistered).
    pub rp_pos: Vec<u32>,
    /// Sort scratch for `preemptible_running` (restores the legacy
    /// ascending-id output order).
    pub rp_buf: Vec<u32>,
    /// Victim-collection buffer handed to
    /// [`crate::sim::SchedPolicy::on_preempt_candidates`].
    pub preempt_victims: Vec<u32>,
    /// Per-task kill count — runs lost to node failures (fault plans
    /// only; drives the retry budget).
    pub kills: Vec<u32>,
    /// Whether each task permanently failed (retry budget exhausted or
    /// dep-cascade; fault plans only).
    pub failed: Vec<bool>,
    /// Kill-victim collection buffer for one node-failure event.
    pub kill_buf: Vec<u32>,
    /// Executed-span records (traced preemption runs only).
    pub spans: Vec<crate::sched::ExecSpan>,
    /// Start time of each task's currently-open execution span for
    /// windowed `busy_core_seconds` accounting (`NAN` when the task is
    /// not running; horizon-bounded runs only).
    pub win_start: Vec<f64>,
    /// Fail instant of each node awaiting failure detection
    /// (`f64::INFINITY` when the node is healthy or its failure was
    /// already detected; degraded runs with `detect_timeout > 0` only).
    pub node_failed_at: Vec<f64>,
    /// Whether each node's current failure has been detected (the node
    /// is retired and its tasks killed; degraded runs only).
    pub node_detected: Vec<bool>,
    /// Per-node heartbeat sequence, bumped on every fail/recover so a
    /// `Suspect` raised before a recovery goes recognisably stale
    /// (degraded runs only).
    pub hb_seq: Vec<u32>,
    /// Consecutive launch-message losses of each task's in-flight
    /// launch (drives the capped exponential backoff; message plans
    /// only).
    pub msg_attempt: Vec<u32>,
    /// Slot of each task's live speculative duplicate (`u32::MAX` =
    /// none; speculation only).
    pub spec_slot: Vec<u32>,
    /// Start time of each task's live speculative duplicate (`NAN`
    /// when none; speculation only).
    pub spec_start: Vec<f64>,
    /// Detection latencies recorded this run (one per detected real
    /// failure, in detection order; degraded runs only).
    pub detect_latencies: Vec<f64>,
    /// Struct-of-arrays mirror of the hot task-spec fields, filled by
    /// the kernel's one-pass workload scan (all runs).
    pub soa: TaskSoa,
    /// Streaming P² estimate of the median scheduler-induced wait.
    pub wait_p50: P2Quantile,
    /// Streaming P² estimate of the 95th-percentile wait.
    pub wait_p95: P2Quantile,
    /// Streaming P² estimate of the 99th-percentile wait.
    pub wait_p99: P2Quantile,
    /// Bounded deterministic reservoir of wait observations — exact at
    /// small n (≤ [`WAIT_SAMPLE_CAP`]), a uniform sample past it.
    pub wait_sample: Reservoir,
}

impl SimScratch {
    /// Empty scratch; buffers grow on first use and stay warm after.
    pub fn new() -> Self {
        Self {
            queue: EventQueue::new(),
            pending: PendingList::new(),
            order: OrderIndex::new(),
            pool: SlotPool::empty(),
            slot_mem: Vec::new(),
            trace: Vec::new(),
            trace_idx: Vec::new(),
            busy_until: Vec::new(),
            indeg: Vec::new(),
            dep_off: Vec::new(),
            dep_edges: Vec::new(),
            submitted: Vec::new(),
            gang_total: Vec::new(),
            gang_ready: Vec::new(),
            extra_span: Vec::new(),
            extra_slots: Vec::new(),
            remaining: Vec::new(),
            span_start: Vec::new(),
            run_slot: Vec::new(),
            epoch: Vec::new(),
            evictions: Vec::new(),
            kernel_alloc: Vec::new(),
            rp_list: Vec::new(),
            rp_pos: Vec::new(),
            rp_buf: Vec::new(),
            preempt_victims: Vec::new(),
            kills: Vec::new(),
            failed: Vec::new(),
            kill_buf: Vec::new(),
            spans: Vec::new(),
            win_start: Vec::new(),
            node_failed_at: Vec::new(),
            node_detected: Vec::new(),
            hb_seq: Vec::new(),
            msg_attempt: Vec::new(),
            spec_slot: Vec::new(),
            spec_start: Vec::new(),
            detect_latencies: Vec::new(),
            soa: TaskSoa::default(),
            wait_p50: P2Quantile::new(0.50),
            wait_p95: P2Quantile::new(0.95),
            wait_p99: P2Quantile::new(0.99),
            wait_sample: Reservoir::new(WAIT_SAMPLE_CAP),
        }
    }

    /// Rewind every buffer for a run of `n_tasks` tasks on `cluster`.
    /// After this call the scratch is indistinguishable from freshly
    /// allocated state (modulo retained capacity).
    pub fn begin(&mut self, cluster: &ClusterSpec, n_tasks: usize, collect_trace: bool) {
        self.queue.reset();
        self.pending.reset(n_tasks);
        self.order.reset();
        self.pool.reinit(cluster);
        self.slot_mem.clear();
        self.slot_mem.resize(self.pool.capacity(), 0);
        self.trace.clear();
        self.trace_idx.clear();
        self.busy_until.clear();
        self.indeg.clear();
        self.dep_off.clear();
        self.dep_edges.clear();
        self.submitted.clear();
        self.gang_total.clear();
        self.gang_ready.clear();
        self.extra_span.clear();
        self.extra_slots.clear();
        self.remaining.clear();
        self.span_start.clear();
        self.run_slot.clear();
        self.epoch.clear();
        self.evictions.clear();
        self.kernel_alloc.clear();
        self.rp_list.clear();
        self.rp_pos.clear();
        self.rp_buf.clear();
        self.preempt_victims.clear();
        self.kills.clear();
        self.failed.clear();
        self.kill_buf.clear();
        self.spans.clear();
        self.win_start.clear();
        self.node_failed_at.clear();
        self.node_detected.clear();
        self.hb_seq.clear();
        self.msg_attempt.clear();
        self.spec_slot.clear();
        self.spec_start.clear();
        self.detect_latencies.clear();
        self.soa.clear();
        self.soa.reserve(n_tasks);
        self.wait_p50.reset();
        self.wait_p95.reset();
        self.wait_p99.reset();
        self.wait_sample.reset();
        if collect_trace {
            self.trace.reserve(n_tasks);
            self.trace_idx.resize(n_tasks, u32::MAX);
        }
    }
}

impl Default for SimScratch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_rewinds_everything() {
        let cluster = ClusterSpec::homogeneous(2, 4, 1024, 2);
        let mut s = SimScratch::new();
        s.begin(&cluster, 10, true);
        // Dirty every buffer.
        s.queue.push(1.0, SimEv::Tick);
        s.pending.push_back(3);
        s.pool.alloc(100).unwrap();
        s.slot_mem[0] = 7;
        s.trace_idx[0] = 5;
        s.busy_until.push(9.0);
        s.indeg.push(2);
        s.dep_off.push(1);
        s.dep_edges.push(4);
        s.submitted.push(true);
        s.gang_total.push(3);
        s.gang_ready.push(1);
        s.extra_span.push((0, 2));
        s.extra_slots.push(6);
        s.remaining.push(1.5);
        s.span_start.push(2.0);
        s.run_slot.push(3);
        s.epoch.push(1);
        s.evictions.push(2);
        s.kernel_alloc.push(true);
        s.rp_list.push(1);
        s.rp_pos.push(0);
        s.rp_buf.push(2);
        s.preempt_victims.push(0);
        s.kills.push(1);
        s.failed.push(true);
        s.kill_buf.push(4);
        s.spans.push(crate::sched::ExecSpan {
            task: 0,
            slot: 0,
            start: 0.0,
            end: 1.0,
        });
        s.win_start.push(3.0);
        s.node_failed_at.push(4.0);
        s.node_detected.push(true);
        s.hb_seq.push(2);
        s.msg_attempt.push(1);
        s.spec_slot.push(3);
        s.spec_start.push(5.0);
        s.detect_latencies.push(0.5);
        s.soa.push(&TaskSpec::array(0, 0, 2.0));
        s.wait_p50.add(1.0);
        s.wait_p95.add(2.0);
        s.wait_p99.add(3.0);
        s.wait_sample.add(4.0);
        s.begin(&cluster, 4, true);
        assert!(s.queue.is_empty());
        assert_eq!(s.queue.now(), 0.0);
        assert!(s.pending.is_empty());
        assert_eq!(s.pool.busy_count(), 0);
        assert_eq!(s.slot_mem, vec![0; 8]);
        assert!(s.trace.is_empty());
        assert_eq!(s.trace_idx, vec![u32::MAX; 4]);
        assert!(s.busy_until.is_empty());
        assert!(s.indeg.is_empty());
        assert!(s.dep_off.is_empty());
        assert!(s.dep_edges.is_empty());
        assert!(s.submitted.is_empty());
        assert!(s.gang_total.is_empty());
        assert!(s.gang_ready.is_empty());
        assert!(s.extra_span.is_empty());
        assert!(s.extra_slots.is_empty());
        assert!(s.remaining.is_empty());
        assert!(s.span_start.is_empty());
        assert!(s.run_slot.is_empty());
        assert!(s.epoch.is_empty());
        assert!(s.evictions.is_empty());
        assert!(s.kernel_alloc.is_empty());
        assert!(s.rp_list.is_empty());
        assert!(s.rp_pos.is_empty());
        assert!(s.rp_buf.is_empty());
        assert!(s.preempt_victims.is_empty());
        assert!(s.kills.is_empty());
        assert!(s.failed.is_empty());
        assert!(s.kill_buf.is_empty());
        assert!(s.spans.is_empty());
        assert!(s.win_start.is_empty());
        assert!(s.node_failed_at.is_empty());
        assert!(s.node_detected.is_empty());
        assert!(s.hb_seq.is_empty());
        assert!(s.msg_attempt.is_empty());
        assert!(s.spec_slot.is_empty());
        assert!(s.spec_start.is_empty());
        assert!(s.detect_latencies.is_empty());
        assert!(s.soa.is_empty());
        assert_eq!(s.wait_p50.count(), 0);
        assert!(s.wait_p50.estimate().is_nan());
        assert_eq!(s.wait_p95.count(), 0);
        assert_eq!(s.wait_p99.count(), 0);
        assert_eq!(s.wait_sample.seen(), 0);
        assert!(s.wait_sample.sample().is_empty());
    }

    #[test]
    fn soa_packs_kinds_and_mirrors_spec_fields() {
        let mut soa = TaskSoa::default();
        let mut t = TaskSpec::array(3, 1, 2.5);
        t.cores = 4;
        t.mem_mb = 512;
        t.submit_at = 1.25;
        soa.push(&t);
        soa.push(&TaskSpec::parallel(4, 2, 1.0, 2));
        soa.push(&TaskSpec::service(5, 3, 2));
        assert_eq!(soa.len(), 3);
        assert_eq!(soa.duration[0], 2.5);
        assert_eq!(soa.submit_at[0], 1.25);
        assert_eq!(soa.cores[0], 4);
        assert_eq!(soa.mem_mb[0], 512);
        assert_eq!(soa.job[0], 1);
        assert_eq!(
            soa.kind,
            vec![
                TaskSoa::KIND_ARRAY,
                TaskSoa::KIND_PARALLEL,
                TaskSoa::KIND_SERVICE
            ]
        );
        assert!(!soa.is_service(0));
        assert!(soa.is_parallel(1));
        assert!(soa.is_service(2));
        soa.clear();
        assert!(soa.is_empty());
    }

    #[test]
    fn trace_buffers_skipped_when_untraced() {
        let cluster = ClusterSpec::homogeneous(1, 2, 1024, 1);
        let mut s = SimScratch::new();
        s.begin(&cluster, 1000, false);
        assert!(s.trace_idx.is_empty());
        assert!(s.trace.is_empty());
    }
}
