//! Reusable per-worker simulation buffers — the zero-allocation core.
//!
//! Every `Scheduler::run` in the seed allocated its event-queue heap,
//! pending queue, slot pool, trace buffers and per-slot memory table
//! from scratch, once per trial. A sweep runs hundreds of trials, so
//! the allocator churn (and the cold pages behind it) sat directly on
//! the hot path. [`SimScratch`] owns all of those buffers; a worker
//! thread creates one and threads it through
//! [`crate::sched::Scheduler::run_with_scratch`] for every cell it
//! executes, so repeated trials reuse warm, already-sized allocations.
//!
//! Correctness contract: [`SimScratch::begin`] rewinds every buffer to
//! the state a fresh allocation would have, so a run through a reused
//! scratch is bit-identical to a run through a new one. The
//! `parallel_determinism` integration test pins this down.

use super::engine::{EventQueue, SimEv};
use crate::cluster::{ClusterSpec, SlotPool};
use crate::workload::TraceRecord;
use std::collections::VecDeque;

/// Warm buffers for one simulation worker.
pub struct SimScratch {
    /// Shared event queue (all simulators use the [`SimEv`] payload).
    pub queue: EventQueue<SimEv>,
    /// Pending-task FIFO (task ids).
    pub pending: VecDeque<u32>,
    /// Core-slot pool, rebuilt in place per run via [`SlotPool::reinit`].
    pub pool: SlotPool,
    /// Memory (MB) held by each slot's current task.
    pub slot_mem: Vec<i64>,
    /// Per-task trace records (only filled when the run collects traces).
    pub trace: Vec<TraceRecord>,
    /// task id -> index into `trace` (`u32::MAX` = not yet started).
    pub trace_idx: Vec<u32>,
    /// Per-slot busy-until times (Sparrow's worker backlogs).
    pub busy_until: Vec<f64>,
    /// Pending job order (batch-queue simulator).
    pub job_order: Vec<u32>,
    /// Running set `(end_time, cores, job index)` (batch-queue simulator).
    pub running: Vec<(f64, u32, u32)>,
}

impl SimScratch {
    /// Empty scratch; buffers grow on first use and stay warm after.
    pub fn new() -> Self {
        Self {
            queue: EventQueue::new(),
            pending: VecDeque::new(),
            pool: SlotPool::empty(),
            slot_mem: Vec::new(),
            trace: Vec::new(),
            trace_idx: Vec::new(),
            busy_until: Vec::new(),
            job_order: Vec::new(),
            running: Vec::new(),
        }
    }

    /// Rewind every buffer for a run of `n_tasks` tasks on `cluster`.
    /// After this call the scratch is indistinguishable from freshly
    /// allocated state (modulo retained capacity).
    pub fn begin(&mut self, cluster: &ClusterSpec, n_tasks: usize, collect_trace: bool) {
        self.queue.reset();
        self.pending.clear();
        self.pool.reinit(cluster);
        self.slot_mem.clear();
        self.slot_mem.resize(self.pool.capacity(), 0);
        self.trace.clear();
        self.trace_idx.clear();
        self.busy_until.clear();
        self.job_order.clear();
        self.running.clear();
        if collect_trace {
            self.trace.reserve(n_tasks);
            self.trace_idx.resize(n_tasks, u32::MAX);
        }
    }

}

impl Default for SimScratch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_rewinds_everything() {
        let cluster = ClusterSpec::homogeneous(2, 4, 1024, 2);
        let mut s = SimScratch::new();
        s.begin(&cluster, 10, true);
        // Dirty every buffer.
        s.queue.push(1.0, SimEv::Tick);
        s.pending.push_back(3);
        s.pool.alloc(100).unwrap();
        s.slot_mem[0] = 7;
        s.trace_idx[0] = 5;
        s.busy_until.push(9.0);
        s.job_order.push(1);
        s.running.push((1.0, 2, 3));
        s.begin(&cluster, 4, true);
        assert!(s.queue.is_empty());
        assert_eq!(s.queue.now(), 0.0);
        assert!(s.pending.is_empty());
        assert_eq!(s.pool.busy_count(), 0);
        assert_eq!(s.slot_mem, vec![0; 8]);
        assert!(s.trace.is_empty());
        assert_eq!(s.trace_idx, vec![u32::MAX; 4]);
        assert!(s.busy_until.is_empty());
        assert!(s.job_order.is_empty());
        assert!(s.running.is_empty());
    }

    #[test]
    fn trace_buffers_skipped_when_untraced() {
        let cluster = ClusterSpec::homogeneous(1, 2, 1024, 1);
        let mut s = SimScratch::new();
        s.begin(&cluster, 1000, false);
        assert!(s.trace_idx.is_empty());
        assert!(s.trace.is_empty());
    }
}
