//! Unified control-plane kernel: one event loop, many policies.
//!
//! Before this module every scheduler backend hand-rolled the same
//! `while let Some((now, ev)) = q.pop()` loop with duplicated
//! submission seeding, trace/wait/makespan accounting, slot
//! alloc/release and `RunResult` assembly — and every one of them
//! ignored the `cores`, `deps`, `submit_at` and `JobKind::Parallel`
//! dimensions that [`crate::workload::TaskSpec`] already declares.
//! [`Kernel::run`] owns all of that *mechanism* once; a backend is now
//! a [`SchedPolicy`] — pure policy logic (when does a dispatch happen,
//! what does the daemon charge for it) expressed through hooks:
//!
//! * [`SchedPolicy::on_submit`] — seed the first control-plane event
//!   (periodic tick, or an immediate dispatch for event-driven
//!   policies) and charge batch-submission costs;
//! * [`SchedPolicy::on_arrive`] — a deferred submission reached the
//!   control plane (charge per-job submission cost);
//! * [`SchedPolicy::on_tick`] — the periodic pass (scheduling cycle,
//!   offer round, heartbeat): scan costs + dispatch via
//!   [`KernelCtx::drain_fifo`];
//! * [`SchedPolicy::on_dispatch`] is expressed as the closure those
//!   drain helpers call per task: it prices one launch and returns a
//!   [`Launch`] (start time, optionally via an intermediate `Stage`);
//! * [`SchedPolicy::on_complete`] — completion bookkeeping; returns
//!   when the task's slots become reusable;
//! * [`SchedPolicy::on_slot_free`] / [`SchedPolicy::on_deps_ready`] —
//!   dispatch opportunities for event-driven (tickless) policies.
//!
//! The kernel makes the dormant workload dimensions real for every
//! policy at once:
//!
//! * **multi-core tasks** — `cores > 1` allocates that many slots
//!   all-or-nothing (with rollback that restores the free-stack order,
//!   so the `cores == 1` path is bit-identical to the historical
//!   per-backend loops);
//! * **DAG dependencies** — `deps` gate admission to the pending queue
//!   via an indegree table + CSR edge list; children are admitted the
//!   moment their last parent's `End` event fires;
//! * **gang scheduling** — `JobKind::Parallel` jobs dispatch
//!   all-or-nothing once every member is ready, and a blocked gang is
//!   skipped over so later tasks can backfill around it;
//! * **arrival processes** — `submit_at > 0` tasks arrive through
//!   `Arrive` events (see [`crate::workload::ArrivalProcess`]).
//!
//! **Preemption subsystem.** When a workload contains preemptible
//! tasks ([`crate::workload::TaskSpec::preemptible`]) the kernel
//! activates evict/requeue mechanics on top of the same event loop:
//!
//! * policies *choose* victims through
//!   [`SchedPolicy::on_preempt_candidates`] (fired after arrivals and
//!   ticks while work is queued); the kernel *executes* the eviction —
//!   [`KernelCtx::request_preempt`] validates the victim (preemptible,
//!   running, kernel-allocated slots; gang-aware all-or-nothing) and
//!   schedules a `Preempt` event;
//! * an eviction closes the victim's productive span (partial work is
//!   preserved: `remaining -= executed`), invalidates its in-flight
//!   `End` via a per-task dispatch epoch, holds the slots for the
//!   task's `checkpoint_cost` before releasing them through the normal
//!   `SlotFree` path (extra multi-core slots in the same order the
//!   `End` path uses), and requeues the task at the back of the
//!   pending queue (so FIFO drains hand the freed slot to the task
//!   that triggered the eviction; ordering combinators re-sort);
//! * re-dispatch goes through the ordinary drain mechanics — a
//!   previously-evicted task launches via a `Resume` event (or is
//!   detected on the staged `Start` path) that runs it for exactly its
//!   remaining work, and notifies the policy via
//!   [`SchedPolicy::on_resume`];
//! * ties always favour work: an `End` and a `Preempt` at the same
//!   instant resolve in insertion order, and the epoch check turns the
//!   loser into a no-op, so a task is never both completed and evicted.
//!
//! Every preemption buffer lives in [`SimScratch`] and is sized only
//! when the workload opts in, so non-preempt runs execute the exact
//! pre-subsystem instruction sequence (bit-identical results) and
//! warm-scratch preempt runs stay allocation-free on the hot path.
//!
//! **Service jobs & horizon-bounded runs.** When
//! [`RunOptions::horizon`] is set the loop becomes a windowed
//! observation instead of a run-to-completion: only events at
//! `t <= horizon` execute, [`JobKind::Service`] tasks occupy their
//! slots from dispatch until the window closes (they are dispatched and
//! priced like any other launch but never schedule an `End`), and the
//! kernel integrates `busy_core_seconds` — every execution span,
//! clipped to the horizon and weighted by the task's core count — for
//! the windowed utilization in [`RunResult`]. Services compose with the
//! preemption subsystem (they are valid eviction victims with the usual
//! checkpoint semantics, resuming for the rest of the window). Without
//! a horizon a `Service` task has no valid semantics, so the kernel
//! refuses to run it (see [`crate::workload::Workload::validate_for`])
//! instead of the historical silent run-as-batch. Horizonless runs take
//! the exact pre-horizon code path: results stay bit-identical.
//!
//! **Fault-injection subsystem.** When [`RunOptions::faults`] carries a
//! non-empty [`crate::cluster::FaultPlan`] the kernel makes node
//! lifecycle a first-class mechanism: the plan's events are seeded into
//! the queue at run start and fire as `NodeFail` / `NodeDrain` /
//! `NodeRecover`:
//!
//! * **failure** retires the node's slots mid-run (the pool parks them;
//!   see [`SlotPool::retire_node`]) and *kills* every task running
//!   there — unlike an eviction, the partial work is **lost**
//!   (`remaining` resets to the full duration and the span is charged
//!   to [`RunResult::wasted_core_seconds`]); gang members die with
//!   their whole gang, services always restart elsewhere, and batch
//!   tasks requeue through a per-task retry budget
//!   ([`crate::workload::TaskSpec::max_retries`]) — a task killed more
//!   times than its budget allows is permanently *failed* (and its
//!   dependents cascade-fail with it, since their indegrees can never
//!   reach zero);
//! * **drain** retires the node for placement but lets running work
//!   finish (nothing is killed; slots park as they release);
//! * **recovery** returns the parked capacity through the same indexed
//!   free-paths ([`SlotPool::restore_node`]).
//!
//! A launch in flight toward a node that dies before its `Start` fires
//! is *aborted*: the slots release (parking), the task silently
//! requeues, and neither the retry budget nor the waste accounting is
//! charged (no work had started). Policies observe lifecycle through
//! [`SchedPolicy::on_node_fail`] / [`SchedPolicy::on_node_drain`] /
//! [`SchedPolicy::on_node_recover`] — tick-driven backends typically
//! need no hook (the next cycle re-dispatches the requeued work, and
//! the parked pool is the rescinded offer), while event-driven backends
//! use them as dispatch opportunities. At equal timestamps fault events
//! fire before same-time `Start`/`End` events (they were seeded first),
//! so a failure always beats a photo-finish completion — deterministic
//! and pessimistic. With an empty plan every gate in this subsystem is
//! statically false and runs are bit-identical to pre-fault builds.
//!
//! **Degraded control plane.** Three optional mechanisms model an
//! *imperfect* control plane on top of the fault subsystem, all seeded
//! and bit-identical for any `--jobs` (see the README's "Degraded
//! control plane" section):
//!
//! * **heartbeat failure detection** ([`RunOptions::detect_timeout`] >
//!   0) — a `NodeFail` no longer retires capacity instantly: the node
//!   keeps accepting (doomed) launches until `detect_timeout` elapses
//!   without a heartbeat, at which point a `Suspect` event retires the
//!   node, kills its tasks (charging the extra work run since the
//!   failure to [`RunResult::undetected_lost_core_seconds`]) and fires
//!   [`SchedPolicy::on_node_suspected`]. A node that recovers inside
//!   the window is a *false alarm*: nothing was killed, nothing fires.
//!   Completions on a failed-but-undetected node cannot be observed —
//!   their `End` defers to the suspicion instant, where the detection
//!   kill (scheduled first, so it wins the FIFO tie) or the recovery
//!   decides their fate;
//! * **message perturbation** ([`RunOptions::messages`]) — launch RPCs
//!   draw an exponential in-flight latency and can be *lost* (retried
//!   with capped exponential backoff while the slots stay held, up to
//!   `max_retries` then force-delivered) and completion notifications
//!   can be *delayed* or *duplicated* (a duplicate `End` is idempotent:
//!   completion bumps the dispatch epoch, so the copy is stale);
//! * **speculative re-execution** ([`RunOptions::speculate_factor`] >
//!   0) — a single-core batch task running `factor ×` its kind's
//!   streaming mean runtime gets a duplicate launch on a free slot;
//!   first completion wins, the loser is killed and charged to
//!   `wasted_core_seconds` (never double-counted as goodput).
//!
//! With `RunOptions::degraded_active()` false every gate is statically
//! false: no buffers are sized, no RNG is drawn, and runs are
//! bit-identical to pre-degraded builds.
//!
//! Determinism contract: for workloads using none of the new
//! dimensions (1-core, dep-free, all-at-once `Array` tasks — the
//! paper's benchmark shape), the kernel replays the exact event and
//! RNG-draw sequence of the pre-kernel per-backend loops, so
//! `t_total`, `daemon_busy` and traces are bit-identical to the
//! pre-refactor implementation (`tests/golden_array.rs` pins this).

use super::engine::{EventQueue, SimEv, Time};
use super::pending::{OrderIndex, OrderMode, PendingList};
use super::scratch::{SimScratch, TaskSoa};
use crate::cluster::{ClusterSpec, FaultKind, MessagePlan, NodeId, SlotId, SlotPool};
use crate::sched::{ExecSpan, RunOptions, RunResult};
use crate::util::prng::Prng;
use crate::util::stats::{P2Quantile, Reservoir, Summary};
use crate::workload::{JobId, JobKind, TaskId, TraceRecord, Workload};

/// How one dispatched task enters execution.
#[derive(Clone, Copy, Debug)]
pub struct Launch {
    /// Absolute virtual time of the launch event.
    pub at: Time,
    /// Route through an intermediate `Stage` event (e.g. YARN's
    /// ApplicationMaster becoming ready) instead of starting directly.
    pub via_stage: bool,
}

impl Launch {
    /// Start executing at `at`.
    pub fn start(at: Time) -> Self {
        Self {
            at,
            via_stage: false,
        }
    }

    /// Reach an intermediate launch stage at `at`; the policy's
    /// [`SchedPolicy::on_stage`] hook decides what happens next.
    pub fn staged(at: Time) -> Self {
        Self {
            at,
            via_stage: true,
        }
    }
}

/// Per-dispatch pricing callback: given `(task, primary slot)`, charge
/// whatever control-plane costs apply and say when the task launches.
pub type LaunchFn<'c> = dyn FnMut(TaskId, SlotId) -> Launch + 'c;

/// A scheduler policy driven by [`Kernel::run`]. Hooks default to
/// no-ops so event-driven and tick-driven policies implement only what
/// they use.
pub trait SchedPolicy {
    /// Display name used in [`RunResult::scheduler`].
    fn label(&self) -> String;

    /// Called once after the kernel has seeded the pending queue
    /// (batch submissions) and `Arrive` events (deferred submissions).
    /// `batch` is the number of tasks submitted at t = 0 as one batch.
    /// Tick-driven policies push their first `Tick` here; event-driven
    /// policies dispatch directly.
    fn on_submit(&mut self, ctx: &mut KernelCtx, batch: usize);

    /// A deferred submission reached the control plane (the task has
    /// already been admitted to the pending queue if its dependencies
    /// are satisfied).
    fn on_arrive(&mut self, _ctx: &mut KernelCtx, _now: Time, _task: TaskId) {}

    /// Periodic control-plane pass (scheduling cycle / offer round /
    /// heartbeat). Only called when [`SchedPolicy::tick_interval`]
    /// returns `Some`.
    fn on_tick(&mut self, _ctx: &mut KernelCtx, _now: Time) {}

    /// Interval between periodic passes; `None` for event-driven
    /// policies. The kernel re-schedules the next tick while tasks
    /// remain incomplete.
    fn tick_interval(&self) -> Option<Time> {
        None
    }

    /// An intermediate launch stage fired (a dispatch returned
    /// [`Launch::staged`]). Policies that never stage keep the default.
    fn on_stage(&mut self, _ctx: &mut KernelCtx, _now: Time, _task: TaskId, _slot: SlotId) {
        unreachable!("policy emitted no Stage events but one fired");
    }

    /// A task finished executing. Charge completion costs and return
    /// the time its slots become reusable, or `None` if the policy
    /// does its own capacity bookkeeping (e.g. Sparrow's per-worker
    /// backlogs never allocate kernel slots).
    fn on_complete(&mut self, ctx: &mut KernelCtx, now: Time, task: TaskId, slot: SlotId)
        -> Option<Time>;

    /// A slot finished teardown and was returned to the pool.
    /// Event-driven policies dispatch here.
    fn on_slot_free(&mut self, _ctx: &mut KernelCtx, _now: Time) {}

    /// One or more dependency-blocked tasks just became ready (their
    /// last parent completed). Policies with no periodic tick and no
    /// slot bookkeeping (Sparrow) dispatch here.
    fn on_deps_ready(&mut self, _ctx: &mut KernelCtx, _now: Time) {}

    /// Preemption decision point, fired after each arrival and each
    /// periodic tick while the pending queue is non-empty — only for
    /// workloads containing preemptible tasks. Append victim task ids
    /// to `out`; the kernel validates each through
    /// [`KernelCtx::request_preempt`] (gang members expand to a whole-
    /// gang eviction) and executes the evictions. The default selects
    /// no victims, so preemption is strictly opt-in per policy.
    fn on_preempt_candidates(&mut self, _ctx: &mut KernelCtx, _now: Time, _out: &mut Vec<TaskId>) {
    }

    /// A previously-evicted task restarted on `slot` for its remaining
    /// work. Its re-dispatch was priced by the ordinary launch closure;
    /// this hook is for restart-specific bookkeeping (counting resumes,
    /// fairshare adjustments).
    fn on_resume(&mut self, _ctx: &mut KernelCtx, _now: Time, _task: TaskId, _slot: SlotId) {}

    /// A node failed: its slots were retired from the pool and every
    /// task running there was killed and requeued (or permanently
    /// failed) *before* this hook fires. Policies doing their own
    /// capacity bookkeeping (Sparrow) mark the dead workers here;
    /// event-driven policies treat it as a dispatch opportunity for the
    /// requeued tasks (slots freed on *other* nodes by multi-core
    /// kills). Tick-driven backends typically need nothing: the next
    /// scheduling cycle re-dispatches in character.
    fn on_node_fail(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    /// A node started draining: no new placement (the pool parks its
    /// free slots), but running work finishes normally. Nothing is
    /// killed, so most policies need no reaction; Sparrow must stop
    /// probing the drained workers.
    fn on_node_drain(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    /// A failed or drained node came back: its parked slots rejoined
    /// the free pool *before* this hook fires. Event-driven policies
    /// dispatch here; tick-driven backends pick the capacity up on the
    /// next cycle.
    fn on_node_recover(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    /// A node's failure was *detected*: under heartbeat-based detection
    /// (`RunOptions::detect_timeout > 0`) a `NodeFail` is invisible to
    /// the control plane until `detect_timeout` elapses without a
    /// heartbeat; only then are the node's slots retired and its tasks
    /// killed — both done *before* this hook fires. This is the
    /// degraded-mode counterpart of [`SchedPolicy::on_node_fail`]
    /// (which fires instead under instant detection), so policies react
    /// the same way: mark dead workers, treat it as a dispatch
    /// opportunity, or do nothing if tick-driven. A node that recovers
    /// inside the window is a false alarm and no hook fires at all.
    fn on_node_suspected(&mut self, _ctx: &mut KernelCtx, _now: Time, _node: NodeId) {}

    /// A launch RPC toward `slot` was lost in flight
    /// (`RunOptions::messages` loss draw); the kernel retries it after
    /// a capped exponential backoff while the slots stay held. Purely
    /// observational — most policies need nothing.
    fn on_message_lost(&mut self, _ctx: &mut KernelCtx, _now: Time, _task: TaskId, _slot: SlotId) {
    }

    /// Seconds the central daemon / master spent busy, for
    /// [`RunResult::daemon_busy`].
    fn daemon_busy(&self) -> f64 {
        0.0
    }
}

/// Mutable simulation state handed to policy hooks: the event queue,
/// pending queue, slot pool and the shared dispatch mechanism
/// (multi-core packing, gang all-or-nothing, dependency admission).
pub struct KernelCtx<'w, 's> {
    workload: &'w Workload,
    /// Struct-of-arrays mirror of the hot task-spec fields (duration,
    /// submit time, cores, memory, job, kind), filled by the workload
    /// scan. The event-loop hot paths read these columns instead of
    /// walking `&[TaskSpec]`, so a million-task run stays cache-linear;
    /// cold paths (eviction specs, retries, ordering keys) keep the
    /// AoS view.
    soa: &'s TaskSoa,
    queue: &'s mut EventQueue<SimEv>,
    pending: &'s mut PendingList,
    /// Incremental ordering overlay (inactive unless an `Ordered`
    /// combinator enables it; see `crate::sched::combinators`).
    order: &'s mut OrderIndex,
    pool: &'s mut SlotPool,
    slot_mem: &'s mut Vec<i64>,
    trace: &'s mut Vec<TraceRecord>,
    trace_idx: &'s mut Vec<u32>,
    busy_until: &'s mut Vec<f64>,
    // Dependency gating (built only when the workload has deps).
    has_deps: bool,
    indeg: &'s mut Vec<u32>,
    dep_off: &'s mut Vec<u32>,
    dep_edges: &'s mut Vec<u32>,
    submitted: &'s mut Vec<bool>,
    // Gang scheduling (built only when the workload has Parallel jobs).
    has_gang: bool,
    gang_total: &'s mut Vec<u32>,
    gang_ready: &'s mut Vec<u32>,
    // Multi-core slot packing (built only when any task needs > 1 core).
    extra_span: &'s mut Vec<(u32, u32)>,
    extra_slots: &'s mut Vec<SlotId>,
    // Preemption subsystem (built only when a task is preemptible).
    has_preempt: bool,
    remaining: &'s mut Vec<f64>,
    span_start: &'s mut Vec<f64>,
    run_slot: &'s mut Vec<u32>,
    epoch: &'s mut Vec<u32>,
    evictions: &'s mut Vec<u32>,
    kernel_alloc: &'s mut Vec<bool>,
    // Running-preemptible registry: the task ids a
    // `preemptible_running` scan would return, maintained
    // incrementally at start/evict/end so victim-selection passes cost
    // O(running preemptible) instead of O(all tasks) each.
    rp_list: &'s mut Vec<u32>,
    rp_pos: &'s mut Vec<u32>,
    rp_buf: &'s mut Vec<u32>,
    spans: &'s mut Vec<ExecSpan>,
    preempt_count: u64,
    // Fault-injection subsystem (built only when RunOptions carries a
    // non-empty FaultPlan).
    has_faults: bool,
    kills: &'s mut Vec<u32>,
    failed: &'s mut Vec<bool>,
    kill_count: u64,
    n_failed: usize,
    wasted_core_seconds: f64,
    // Degraded control plane (built only when
    // RunOptions::degraded_active(); see the module docs).
    has_degraded: bool,
    msg: MessagePlan,
    msg_rng: Prng,
    detect_timeout: Time,
    speculate_factor: f64,
    node_failed_at: &'s mut Vec<f64>,
    node_detected: &'s mut Vec<bool>,
    hb_seq: &'s mut Vec<u32>,
    msg_attempt: &'s mut Vec<u32>,
    spec_slot: &'s mut Vec<u32>,
    spec_start: &'s mut Vec<f64>,
    detect_latencies: &'s mut Vec<f64>,
    undetected_lost: f64,
    messages_lost: u64,
    messages_duplicated: u64,
    spec_launches: u64,
    spec_kills: u64,
    // Streaming per-kind runtime estimate (count, mean) feeding the
    // speculation deadline; indexed by the TaskSoa kind byte.
    spec_est_count: [u64; 3],
    spec_est_mean: [f64; 3],
    // Windowed accounting (built only for horizon-bounded runs).
    horizon: Option<Time>,
    win_start: &'s mut Vec<f64>,
    busy_core_seconds: f64,
    // Kernel-owned accounting.
    collect_trace: bool,
    completed: usize,
    makespan: f64,
    waits: Summary,
    // Streaming wait metrics: O(1) P² percentile markers plus a bounded
    // reservoir, so quantiles survive in the result without an O(n)
    // trace (the traced mode stays the exact oracle at small n).
    wait_p50: &'s mut P2Quantile,
    wait_p95: &'s mut P2Quantile,
    wait_p99: &'s mut P2Quantile,
    wait_sample: &'s mut Reservoir,
}

impl<'w> KernelCtx<'w, '_> {
    /// The workload being simulated (lives as long as the run, so the
    /// reference can be held across mutable ctx calls).
    pub fn workload(&self) -> &'w Workload {
        self.workload
    }

    /// Schedule a raw simulation event (policies use this for their
    /// first `Tick` and for `Stage` → `Start` transitions).
    pub fn push(&mut self, at: Time, ev: SimEv) {
        self.queue.push(at, ev);
    }

    /// Number of currently free core slots.
    pub fn free_slots(&self) -> usize {
        self.pool.free_count()
    }

    /// Total core-slot capacity of the cluster.
    pub fn capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Number of tasks admitted and awaiting dispatch.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// True if further events are queued at exactly this timestamp.
    /// Policies that must see a *complete* instant (all same-time
    /// releases/arrivals applied) before making dispatch decisions —
    /// e.g. EASY backfill's reservation test — defer their drain until
    /// this returns false.
    pub fn has_more_events_at(&self, now: Time) -> bool {
        self.queue.next_time() == Some(now)
    }

    /// Snapshot of the pending queue in dispatch order: FIFO insertion
    /// order normally, overlay (priority/fairshare) order when an
    /// [`Ordered`](crate::sched::combinators::Ordered) combinator is
    /// active — exactly the order the legacy eagerly-sorted deque
    /// exposed.
    pub fn pending_snapshot(&self) -> Vec<TaskId> {
        let mut v: Vec<TaskId> = self.pending.iter().collect();
        if self.order.is_active() {
            self.order.sort_ids(&mut v, &self.workload.tasks);
        }
        v
    }

    /// Iterate the pending queue without copying it, in FIFO insertion
    /// order. When an ordering overlay is active the *dispatch* order
    /// differs — order-sensitive callers use [`KernelCtx::pending_snapshot`]
    /// or [`KernelCtx::best_priority_pending`] instead; the remaining
    /// users of this iterator are order-insensitive (sums, maxima).
    pub fn pending_ids(&self) -> impl Iterator<Item = TaskId> + '_ {
        self.pending.iter()
    }

    /// Activate the incremental ordering overlay for this run: pending
    /// tasks dispatch in `mode` order from now on (drains, gang member
    /// collection and snapshots all follow it). Called once, from the
    /// `Ordered` combinator's `on_submit`.
    pub fn enable_order(&mut self, mode: OrderMode) {
        self.order.enable(mode, &self.workload.tasks, self.pending);
    }

    /// Whether an ordering overlay is active.
    pub fn order_active(&self) -> bool {
        self.order.is_active()
    }

    /// Charge fairshare usage to `user` (no-op unless the fairshare
    /// overlay is active). O(1): usage ranks whole users, so the index
    /// never needs a rebuild.
    pub fn order_charge(&mut self, user: u32, core_seconds: f64) {
        self.order.charge(user, core_seconds);
    }

    /// Differential-oracle hook: rebuild the overlay index from scratch
    /// with a full legacy-style sort over the pending set. Behaviour is
    /// bit-identical to the incremental maintenance (the equivalence
    /// suite asserts it); only the cost differs — this is the baseline
    /// the `scale` experiment's ordered-queue speedup is measured
    /// against.
    pub fn order_rebuild_eager(&mut self) {
        self.order.rebuild_eager(&self.workload.tasks, self.pending);
    }

    /// The maximal-priority pending task with the legacy tie-break
    /// (first in dispatch order among ties) — the head the `Preemptive`
    /// combinator sizes evictions for. O(log n) under a priority
    /// overlay, O(users) under fairshare, O(pending) otherwise (the
    /// legacy scan).
    pub fn best_priority_pending(&mut self) -> Option<TaskId> {
        if self.pending.is_empty() {
            return None;
        }
        if self.order.is_active() {
            return self
                .order
                .best_priority_head(self.pending, &self.workload.tasks);
        }
        let tasks = &self.workload.tasks;
        self.pending.iter().reduce(|best, t| {
            if tasks[t as usize].priority > tasks[best as usize].priority {
                t
            } else {
                best
            }
        })
    }

    /// True when the kernel's preemption subsystem is active for this
    /// run (the workload contains at least one preemptible task).
    pub fn preempt_enabled(&self) -> bool {
        self.has_preempt
    }

    /// True when the fault-injection subsystem is active for this run
    /// (the run options carry a non-empty fault plan).
    pub fn faults_enabled(&self) -> bool {
        self.has_faults
    }

    /// Number of node-failure kills a task has absorbed so far (0 when
    /// the fault subsystem is inactive).
    pub fn kill_count_of(&self, task: TaskId) -> u32 {
        if self.has_faults {
            self.kills[task as usize]
        } else {
            0
        }
    }

    /// Whether a task has permanently failed (retry budget exhausted,
    /// or a dependency of it did).
    pub fn task_failed(&self, task: TaskId) -> bool {
        self.has_faults && self.failed[task as usize]
    }

    /// Per-task run-state tracking (`remaining`/`span_start`/`run_slot`
    /// /epochs) is shared by the preemption, fault and degraded
    /// control-plane subsystems; any one switches it on.
    fn tracked(&self) -> bool {
        self.has_preempt || self.has_faults || self.has_degraded
    }

    /// True when the degraded control plane is active for this run
    /// (non-empty message plan, heartbeat detection, or speculation).
    pub fn degraded_enabled(&self) -> bool {
        self.has_degraded
    }

    /// Heartbeat-based failure detection active (`detect_timeout` > 0).
    fn has_detection(&self) -> bool {
        self.has_degraded && self.detect_timeout > 0.0
    }

    /// Message perturbation active (non-empty `MessagePlan`).
    fn msg_active(&self) -> bool {
        self.has_degraded && !self.msg.is_empty()
    }

    /// Speculative re-execution active (`speculate_factor` > 0).
    fn spec_active(&self) -> bool {
        self.has_degraded && self.speculate_factor > 0.0
    }

    /// Collect every currently-evictable task into `out`: running,
    /// marked preemptible, and holding kernel-allocated slots (policies
    /// that do their own capacity bookkeeping, like Sparrow, never
    /// produce evictable tasks). Served from the incrementally
    /// maintained registry in O(R log R) for R running preemptible
    /// tasks — the legacy implementation scanned the whole task list
    /// per call; sorting restores its ascending-id output order.
    pub fn preemptible_running(&mut self, out: &mut Vec<TaskId>) {
        if !self.has_preempt {
            return;
        }
        self.rp_buf.clear();
        self.rp_buf.extend_from_slice(&self.rp_list[..]);
        self.rp_buf.sort_unstable();
        out.extend_from_slice(&self.rp_buf[..]);
    }

    /// Register a task as running-preemptible (start/resume path).
    fn rp_add(&mut self, task: TaskId) {
        let i = task as usize;
        debug_assert_eq!(self.rp_pos[i], u32::MAX, "task {task} registered twice");
        self.rp_pos[i] = self.rp_list.len() as u32;
        self.rp_list.push(task);
    }

    /// Unregister on evict/end; a task that was never registered
    /// (non-preemptible, or placed outside the kernel pool) is a no-op.
    fn rp_remove(&mut self, task: TaskId) {
        let i = task as usize;
        let pos = self.rp_pos[i];
        if pos == u32::MAX {
            return;
        }
        self.rp_pos[i] = u32::MAX;
        let last = self.rp_list.pop().expect("registry holds the task");
        if last != task {
            self.rp_list[pos as usize] = last;
            self.rp_pos[last as usize] = pos;
        }
    }

    /// Start time of a task's current execution span (`NAN` if the
    /// task is not running or preemption is inactive).
    pub fn span_start_of(&self, task: TaskId) -> Time {
        if self.has_preempt {
            self.span_start[task as usize]
        } else {
            f64::NAN
        }
    }

    /// Remaining productive work of a task (its full duration when it
    /// has not run yet or preemption is inactive).
    pub fn remaining_of(&self, task: TaskId) -> f64 {
        if self.has_preempt {
            self.remaining[task as usize]
        } else {
            self.workload.tasks[task as usize].duration
        }
    }

    /// How many times a task has been evicted so far this run.
    pub fn eviction_count(&self, task: TaskId) -> u32 {
        if self.has_preempt {
            self.evictions[task as usize]
        } else {
            0
        }
    }

    /// Core slots currently held by the running members of a parallel
    /// job (what a whole-gang eviction would free).
    pub fn running_gang_cores(&self, job: JobId) -> usize {
        if !self.has_preempt {
            return 0;
        }
        self.workload
            .tasks
            .iter()
            .filter(|t| {
                t.job == job
                    && t.kind == JobKind::Parallel
                    && self.run_slot[t.id as usize] != u32::MAX
            })
            .map(|t| t.cores as usize)
            .sum()
    }

    /// Whether [`KernelCtx::request_preempt`] would accept `task` right
    /// now (the same validation, no side effects): the task must be
    /// running on kernel-allocated slots and marked preemptible. Gang
    /// members are judged as a whole-gang all-or-nothing eviction —
    /// refused if any running member is non-preemptible, or any member
    /// is mid-launch or pending (a partial eviction would break gang
    /// atomicity). Victim-selection policies check this before
    /// accounting freed capacity, so a refusal never leaves phantom
    /// in-flight evictions on their books.
    pub fn evictable(&self, task: TaskId) -> bool {
        if !self.has_preempt {
            return false;
        }
        let spec = &self.workload.tasks[task as usize];
        if spec.kind == JobKind::Parallel {
            let mut any_running = false;
            for t in &self.workload.tasks {
                if t.job != spec.job || t.kind != JobKind::Parallel {
                    continue;
                }
                let i = t.id as usize;
                if self.run_slot[i] != u32::MAX {
                    if !t.preemptible || !self.kernel_alloc[i] {
                        return false;
                    }
                    any_running = true;
                } else if self.kernel_alloc[i] || self.pending.contains(t.id) {
                    // Mid-launch or requeued member: evicting the rest
                    // would leave the gang in a mixed state.
                    return false;
                }
            }
            any_running
        } else {
            let i = task as usize;
            spec.preemptible && self.run_slot[i] != u32::MAX && self.kernel_alloc[i]
        }
    }

    /// Request the eviction of `task` at `now`, validating it through
    /// [`KernelCtx::evictable`]. On success `Preempt` events are
    /// scheduled at `now` (one per running gang member for parallel
    /// jobs); a victim that completes or restarts in the meantime turns
    /// its eviction into a no-op (the dispatch epoch moved on). Returns
    /// whether the request was accepted.
    pub fn request_preempt(&mut self, now: Time, task: TaskId) -> bool {
        if !self.evictable(task) {
            return false;
        }
        let spec = &self.workload.tasks[task as usize];
        if spec.kind == JobKind::Parallel {
            for tid in 0..self.workload.tasks.len() as u32 {
                let t = &self.workload.tasks[tid as usize];
                if t.job == spec.job
                    && t.kind == JobKind::Parallel
                    && self.run_slot[tid as usize] != u32::MAX
                {
                    let epoch = self.epoch[tid as usize];
                    self.queue.push(now, SimEv::Preempt { task: tid, epoch });
                }
            }
        } else {
            let epoch = self.epoch[task as usize];
            self.queue.push(now, SimEv::Preempt { task, epoch });
        }
        true
    }

    /// Per-slot busy-until table for policies that model worker-local
    /// backlogs instead of allocating kernel slots (Sparrow).
    pub fn busy_until(&mut self) -> &mut Vec<f64> {
        &mut *self.busy_until
    }

    /// Home node of a core slot. Policies doing their own capacity
    /// bookkeeping use this to map fault events onto their per-slot
    /// state (Sparrow masks the dead node's worker backlogs).
    pub fn node_of_slot(&self, slot: SlotId) -> NodeId {
        self.pool.node_of(slot)
    }

    /// Whether a node currently accepts placements (healthy, not
    /// failed or drained).
    pub fn node_placeable(&self, node: NodeId) -> bool {
        self.pool.node_placeable(node)
    }

    /// True when every member of a `Parallel` job is admitted and
    /// waiting in the pending queue (the gang can be dispatched).
    pub fn gang_all_ready(&self, job: JobId) -> bool {
        if !self.has_gang {
            return false;
        }
        let j = job as usize;
        self.gang_total[j] > 0 && self.gang_ready[j] == self.gang_total[j]
    }

    /// Pending members of a `Parallel` job, in dispatch order (FIFO, or
    /// overlay order under an ordering combinator). Non-gang tasks that
    /// happen to share the job id are not members.
    pub fn pending_members(&self, job: JobId) -> Vec<TaskId> {
        let mut v: Vec<TaskId> = self
            .pending
            .iter()
            .filter(|&t| self.soa.is_parallel(t) && self.soa.job[t as usize] == job)
            .collect();
        if self.order.is_active() {
            self.order.sort_ids(&mut v, &self.workload.tasks);
        }
        v
    }

    /// Remove `task` from the pending queue (with gang-readiness
    /// bookkeeping). Returns false if it was not pending. For policies
    /// that place tasks without kernel slot allocation; pair with
    /// [`KernelCtx::push`]ing the `Start` event. O(1) — the legacy
    /// implementation scanned the queue for the task's position on
    /// every call.
    pub fn take_task(&mut self, task: TaskId) -> bool {
        if !self.pending.contains(task) {
            return false;
        }
        self.remove_pending(task);
        true
    }

    /// The standard FIFO dispatch drain shared by the tick-driven
    /// policies: walk the pending queue in order, allocate slots
    /// (multi-core all-or-nothing), dispatch gangs atomically, skip
    /// over blocked gangs so later tasks backfill, and stop at the
    /// first ordinary task that does not fit (head-of-line blocking,
    /// exactly as the historical per-backend loops did). `launch`
    /// prices each dispatch.
    ///
    /// Allocation note: the pure-array path allocates nothing
    /// (`tried_gangs` only allocates on first push), preserving the
    /// zero-alloc sweep contract; gang attempts allocate small
    /// member/rollback vectors, bounded by gangs per pass. With an
    /// ordering overlay active, the walk follows the incremental index
    /// instead — same dispatch decisions the eagerly-sorted legacy
    /// queue produced, at O((dispatched + 1)·log n) per pass.
    pub fn drain_fifo(&mut self, launch: &mut LaunchFn) {
        if self.order.is_active() {
            self.drain_ordered(launch);
            return;
        }
        let mut tried_gangs: Vec<JobId> = Vec::new();
        let mut cur = self.pending.first();
        while let Some(tid) = cur {
            if self.soa.is_parallel(tid) {
                let job = self.soa.job[tid as usize];
                if tried_gangs.contains(&job) {
                    cur = self.pending.next_of(tid);
                    continue;
                }
                if self.gang_all_ready(job) && self.try_dispatch_gang(job, launch) {
                    // The cursor went with its gang; resume at the first
                    // survivor after it in the old order by chasing the
                    // removed nodes' (intentionally stale) next
                    // pointers — the linked-list equivalent of the old
                    // "re-examine index i" after a mid-queue removal.
                    let mut nxt = self.pending.next_of(tid);
                    while let Some(t) = nxt {
                        if self.pending.contains(t) {
                            break;
                        }
                        nxt = self.pending.next_of(t);
                    }
                    cur = nxt;
                    continue;
                }
                tried_gangs.push(job);
                cur = self.pending.next_of(tid);
                continue;
            }
            match self.alloc_task(tid) {
                Some(primary) => {
                    let nxt = self.pending.next_of(tid);
                    self.remove_pending(tid);
                    let l = launch(tid, primary);
                    self.emit_launch(tid, primary, l);
                    cur = nxt;
                }
                None => break,
            }
        }
    }

    /// Overlay-ordered drain: pop candidates off the incremental index
    /// in dispatch order. A blocked ordinary head stops the walk (its
    /// entry is stashed and survives); blocked or duplicate-attempted
    /// gang members are stashed and skipped, exactly mirroring the FIFO
    /// walk's `tried_gangs` semantics over the sorted order.
    fn drain_ordered(&mut self, launch: &mut LaunchFn) {
        debug_assert!(self.order.tried_gangs.is_empty());
        loop {
            let Some(entry) = self.order.pop_front(self.pending) else {
                break;
            };
            let tid = entry as u32;
            if self.soa.is_parallel(tid) {
                let job = self.soa.job[tid as usize];
                if self.order.tried_gangs.contains(&job) {
                    self.order.stash_entry(entry);
                    continue;
                }
                if self.gang_all_ready(job) && self.try_dispatch_gang(job, launch) {
                    continue; // the entry's task dispatched with its gang
                }
                self.order.tried_gangs.push(job);
                self.order.stash_entry(entry);
                continue;
            }
            match self.alloc_task(tid) {
                Some(primary) => {
                    self.remove_pending(tid);
                    let l = launch(tid, primary);
                    self.emit_launch(tid, primary, l);
                }
                None => {
                    self.order.stash_entry(entry);
                    break;
                }
            }
        }
        self.order.end_walk(&self.workload.tasks);
    }

    /// Attempt to dispatch one specific pending task (policies that
    /// impose their own queue order — priority, fairshare, backfill —
    /// call this per candidate). Returns false if the task is not
    /// pending or its slots cannot all be allocated. Membership is O(1)
    /// — the legacy implementation paid a full queue scan per call,
    /// which made every `OrderedDrain` pass quadratic.
    pub fn try_dispatch(&mut self, task: TaskId, launch: &mut LaunchFn) -> bool {
        if !self.pending.contains(task) {
            return false;
        }
        let Some(primary) = self.alloc_task(task) else {
            return false;
        };
        self.remove_pending(task);
        let l = launch(task, primary);
        self.emit_launch(task, primary, l);
        true
    }

    // ---- internal mechanism -------------------------------------------------

    fn remove_pending(&mut self, tid: TaskId) {
        let removed = self.pending.remove(tid);
        debug_assert!(removed, "task {tid} was not pending");
        if self.has_gang && self.soa.is_parallel(tid) {
            self.gang_ready[self.soa.job[tid as usize] as usize] -= 1;
        }
    }

    /// Admit a submitted task: enqueue it if its dependencies are met.
    fn admit(&mut self, tid: TaskId) {
        if self.has_deps {
            self.submitted[tid as usize] = true;
            if self.indeg[tid as usize] > 0 {
                return;
            }
        }
        self.enqueue_ready(tid);
    }

    fn enqueue_ready(&mut self, tid: TaskId) {
        self.pending.push_back(tid);
        self.order.push(tid, &self.workload.tasks);
        if self.has_gang && self.soa.is_parallel(tid) {
            self.gang_ready[self.soa.job[tid as usize] as usize] += 1;
        }
    }

    /// Execute one validated eviction: close the productive span,
    /// preserve the partial work, invalidate the in-flight `End`,
    /// schedule the slot releases after the checkpoint drain (the same
    /// primary-then-extras order the `End` path uses, so the pool's
    /// free-stack evolution matches a completion at the same instant),
    /// and requeue the task.
    fn execute_evict(&mut self, now: Time, task: TaskId) {
        let spec = &self.workload.tasks[task as usize];
        let i = task as usize;
        let primary = self.run_slot[i];
        debug_assert!(primary != u32::MAX, "evicting idle task {task}");
        if self.spec_active() && self.spec_slot[i] != u32::MAX {
            // The eviction invalidates the run the duplicate was racing.
            self.kill_duplicate(now, task);
        }
        if self.collect_trace {
            self.spans.push(ExecSpan {
                task,
                slot: primary,
                start: self.span_start[i],
                end: now,
            });
        }
        if self.horizon.is_some() {
            // Close the windowed span now: an evicted task may never
            // restart before the window ends, so its trace record must
            // already reflect the progress observed so far (a later End
            // or the window-close pass overwrites it if it does run
            // again).
            self.busy_core_seconds += spec.cores as f64 * (now - self.win_start[i]);
            self.win_start[i] = f64::NAN;
            if self.collect_trace {
                self.trace[self.trace_idx[i] as usize].end = now;
            }
        }
        let executed = now - self.span_start[i];
        self.remaining[i] = (self.remaining[i] - executed).max(0.0);
        self.epoch[i] += 1; // the in-flight End is now stale
        self.evictions[i] += 1;
        self.preempt_count += 1;
        self.span_start[i] = f64::NAN;
        self.run_slot[i] = u32::MAX;
        self.kernel_alloc[i] = false;
        self.rp_remove(task);
        let free_at = now + spec.checkpoint_cost;
        self.queue.push(free_at, SimEv::SlotFree { slot: primary });
        if !self.extra_span.is_empty() {
            let (s0, len) = self.extra_span[i];
            for k in 0..len {
                let s = self.extra_slots[(s0 + k) as usize];
                self.queue.push(free_at, SimEv::SlotFree { slot: s });
            }
        }
        // Requeue at the back: under a plain FIFO drain the task that
        // triggered the eviction (already queued ahead) wins the freed
        // slot; ordering combinators re-impose their discipline anyway.
        self.enqueue_ready(task);
    }

    /// Collect every running task with a slot (primary or extra) on
    /// `node` into `out`, then expand gang members to their whole
    /// running gang — gangs die atomically. Scan order (ascending task
    /// id, then expansion order) is deterministic. O(tasks) per fault
    /// event; fault events are rare.
    fn collect_kill_victims(&self, node: NodeId, out: &mut Vec<TaskId>) {
        out.clear();
        for t in &self.workload.tasks {
            let i = t.id as usize;
            let slot = self.run_slot[i];
            if slot == u32::MAX {
                continue;
            }
            let mut hit = self.pool.node_of(slot) == node;
            if !hit && !self.extra_span.is_empty() && self.kernel_alloc[i] {
                let (s0, len) = self.extra_span[i];
                for k in 0..len {
                    let s = self.extra_slots[(s0 + k) as usize];
                    if self.pool.node_of(s) == node {
                        hit = true;
                        break;
                    }
                }
            }
            if hit {
                out.push(t.id);
            }
        }
        if self.has_gang {
            let mut k = 0;
            while k < out.len() {
                let spec = &self.workload.tasks[out[k] as usize];
                if spec.kind == JobKind::Parallel {
                    for t in &self.workload.tasks {
                        if t.job == spec.job
                            && t.kind == JobKind::Parallel
                            && self.run_slot[t.id as usize] != u32::MAX
                            && !out.contains(&t.id)
                        {
                            out.push(t.id);
                        }
                    }
                }
                k += 1;
            }
        }
    }

    /// Kill one running task after a node failure. Unlike
    /// [`KernelCtx::execute_evict`], the partial work is *lost*:
    /// `remaining` resets to the full duration and the span is charged
    /// to `wasted_core_seconds`. The slots release immediately (the
    /// pool parks the ones on the retired node and re-frees the rest),
    /// and the task either requeues (services always; batch while the
    /// retry budget holds) or permanently fails.
    fn execute_kill(&mut self, now: Time, task: TaskId) {
        let spec = &self.workload.tasks[task as usize];
        let i = task as usize;
        let primary = self.run_slot[i];
        debug_assert!(primary != u32::MAX, "killing idle task {task}");
        if self.spec_active() && self.spec_slot[i] != u32::MAX {
            // The kill restarts the task from scratch; the duplicate
            // was racing a run that no longer exists.
            self.kill_duplicate(now, task);
        }
        if self.collect_trace {
            self.spans.push(ExecSpan {
                task,
                slot: primary,
                start: self.span_start[i],
                end: now,
            });
            // The task may never run again: its trace record must
            // already be closed (a later End or the window-close pass
            // overwrites it if it does).
            self.trace[self.trace_idx[i] as usize].end = now;
        }
        if self.horizon.is_some() {
            self.busy_core_seconds += spec.cores as f64 * (now - self.win_start[i]);
            self.win_start[i] = f64::NAN;
        }
        // A kill at t <= horizon lies fully inside the window, so the
        // whole span is wasted — no clipping needed.
        self.wasted_core_seconds += spec.cores as f64 * (now - self.span_start[i]);
        // The cluster was busy (if fruitlessly) until the kill: the
        // makespan covers it even when the task never completes.
        self.makespan = self.makespan.max(now);
        self.remaining[i] = spec.duration; // work LOST, not banked
        self.epoch[i] += 1; // the in-flight End is now stale
        self.kills[i] += 1;
        self.kill_count += 1;
        self.span_start[i] = f64::NAN;
        self.run_slot[i] = u32::MAX;
        let had_slots = self.kernel_alloc[i];
        self.kernel_alloc[i] = false;
        self.rp_remove(task);
        if had_slots {
            // Same primary-then-extras order the End path uses; the
            // pool parks slots on the retired node and re-frees extras
            // that live on healthy nodes.
            self.pool.release(primary, self.slot_mem[primary as usize]);
            if !self.extra_span.is_empty() {
                let (s0, len) = self.extra_span[i];
                for k in 0..len {
                    let s = self.extra_slots[(s0 + k) as usize];
                    self.pool.release(s, self.slot_mem[s as usize]);
                }
            }
        }
        if self.failed[i] {
            // Already cascade-failed earlier in this kill batch.
            return;
        }
        if spec.kind == JobKind::Service || self.kills[i] <= spec.max_retries {
            self.enqueue_ready(task);
        } else {
            self.fail_task(task);
        }
    }

    /// Permanently fail a task: retry budget exhausted, or (cascade) a
    /// dependency of it failed so its indegree can never reach zero. A
    /// failed gang member leaves its gang (mirroring completion), so
    /// the survivors can still assemble and re-dispatch.
    fn fail_task(&mut self, task: TaskId) {
        let i = task as usize;
        if self.failed[i] {
            return;
        }
        self.failed[i] = true;
        self.n_failed += 1;
        if self.pending.contains(task) {
            // Dead overlay entries are lazily skimmed against the
            // pending list, so removing from `pending` is enough.
            self.remove_pending(task);
        }
        if self.has_gang {
            let t = &self.workload.tasks[i];
            if t.kind == JobKind::Parallel {
                self.gang_total[t.job as usize] -= 1;
            }
        }
        if self.has_deps {
            // Cascade: a dependent of a failed task was never admitted
            // (its indegree stays > 0 forever), so recursing cannot
            // meet a running or pending task.
            let a = self.dep_off[i] as usize;
            let b = self.dep_off[i + 1] as usize;
            for k in a..b {
                let d = self.dep_edges[k];
                self.fail_task(d);
            }
        }
    }

    /// Whether a launch event targeting `slot` would start the task on
    /// a node that has since failed or drained (any of its slots, for
    /// multi-core tasks).
    fn dead_launch(&self, task: TaskId, slot: SlotId) -> bool {
        if !self.pool.node_placeable(self.pool.node_of(slot)) {
            return true;
        }
        if !self.extra_span.is_empty() && self.kernel_alloc[task as usize] {
            let (s0, len) = self.extra_span[task as usize];
            for k in 0..len {
                let s = self.extra_slots[(s0 + k) as usize];
                if !self.pool.node_placeable(self.pool.node_of(s)) {
                    return true;
                }
            }
        }
        false
    }

    /// Abort a launch whose target node died between dispatch and
    /// `Start`: release the slots (the retired ones park) and silently
    /// requeue the task. No span was opened, so neither the retry
    /// budget nor the waste accounting is charged — the dispatch cost
    /// the policy already paid is sunk, as in a real control plane.
    fn abort_launch(&mut self, task: TaskId, slot: SlotId) {
        let i = task as usize;
        let had_slots = self.kernel_alloc[i];
        self.kernel_alloc[i] = false;
        if had_slots {
            self.pool.release(slot, self.slot_mem[slot as usize]);
            if !self.extra_span.is_empty() {
                let (s0, len) = self.extra_span[i];
                for k in 0..len {
                    let s = self.extra_slots[(s0 + k) as usize];
                    self.pool.release(s, self.slot_mem[s as usize]);
                }
            }
        }
        if !self.failed[i] {
            self.enqueue_ready(task);
        }
    }

    // ---- degraded control plane ---------------------------------------------

    /// Loss draw for a launch RPC firing now. A lost launch bumps the
    /// task's attempt counter (the caller re-pushes the event after
    /// [`MessagePlan::backoff_delay`]); once the retry budget is spent
    /// the message is force-delivered so a run can never stall on bad
    /// luck. Delivery resets the counter.
    fn launch_lost(&mut self, task: TaskId) -> bool {
        let i = task as usize;
        if self.msg_attempt[i] >= self.msg.max_retries {
            self.msg_attempt[i] = 0;
            return false;
        }
        if self.msg_rng.chance(self.msg.loss_prob) {
            self.msg_attempt[i] += 1;
            self.messages_lost += 1;
            true
        } else {
            self.msg_attempt[i] = 0;
            false
        }
    }

    /// If any node hosting `task`'s slots is failed but not yet
    /// detected, a completion fired there cannot be observed by the
    /// control plane: returns the earliest suspicion instant to defer
    /// the `End` to. The detection kill was queued at that instant
    /// *before* the deferred copy, so it wins the FIFO tie and stales
    /// the `End` via the epoch bump; if the node recovered in the
    /// window (false alarm) the deferred `End` completes then.
    fn end_deferral(&self, task: TaskId, slot: SlotId) -> Option<Time> {
        let check = |s: SlotId| -> Option<Time> {
            let node = self.pool.node_of(s) as usize;
            let fa = self.node_failed_at[node];
            (fa.is_finite() && !self.node_detected[node]).then(|| fa + self.detect_timeout)
        };
        let mut at = check(slot);
        if !self.extra_span.is_empty() && self.kernel_alloc[task as usize] {
            let (s0, len) = self.extra_span[task as usize];
            for k in 0..len {
                let s = self.extra_slots[(s0 + k) as usize];
                match (at, check(s)) {
                    (Some(a), Some(b)) => at = Some(a.min(b)),
                    (None, b @ Some(_)) => at = b,
                    _ => {}
                }
            }
        }
        at
    }

    /// Kill one victim of a *detected* node failure: same semantics as
    /// [`KernelCtx::execute_kill`], plus the work the task ran between
    /// the physical failure and its detection (doomed, invisible to the
    /// scheduler) is charged to `undetected_lost_core_seconds`.
    fn execute_kill_detected(&mut self, now: Time, task: TaskId, failed_at: Time) {
        let i = task as usize;
        let cores = self.soa.cores[i] as f64;
        let lost_from = self.span_start[i].max(failed_at);
        self.undetected_lost += cores * (now - lost_from);
        self.execute_kill(now, task);
    }

    /// Launch a speculative duplicate of a running task on a free pool
    /// slot (no-op when the pool is full — speculation never preempts).
    /// The duplicate is kernel-owned: it occupies exactly one slot
    /// (speculation is gated to single-core batch tasks), runs the full
    /// duration, and resolves first-completion-wins against the primary.
    fn launch_speculative(&mut self, now: Time, task: TaskId) {
        let i = task as usize;
        let mem = self.soa.mem_mb[i];
        let Some(slot) = self.pool.alloc(mem) else {
            return;
        };
        self.slot_mem[slot as usize] = mem;
        self.spec_slot[i] = slot;
        self.spec_start[i] = now;
        self.spec_launches += 1;
        let mut end = now + self.soa.duration[i];
        if self.msg_active() && self.msg.completion_latency_mean > 0.0 {
            end += self.msg_rng.exponential(self.msg.completion_latency_mean);
        }
        let epoch = self.epoch[i];
        self.queue.push(end, SimEv::SpecEnd { task, slot, epoch });
    }

    /// Kill a task's speculative duplicate (the primary completed,
    /// was evicted, was killed, or the duplicate's node died): its span
    /// is pure duplicate overhead, charged to `wasted_core_seconds`.
    /// The in-flight `SpecEnd` goes stale via the cleared `spec_slot`.
    fn kill_duplicate(&mut self, now: Time, task: TaskId) {
        let i = task as usize;
        let slot = self.spec_slot[i];
        debug_assert!(slot != u32::MAX, "task {task} has no duplicate");
        let cores = self.soa.cores[i] as f64;
        let ran = now - self.spec_start[i];
        self.wasted_core_seconds += cores * ran;
        if self.horizon.is_some() {
            // The duplicate occupied real capacity: busy, if fruitless.
            self.busy_core_seconds += cores * ran;
        }
        if self.collect_trace {
            self.spans.push(ExecSpan {
                task,
                slot,
                start: self.spec_start[i],
                end: now,
            });
        }
        self.spec_kills += 1;
        self.spec_slot[i] = u32::MAX;
        self.spec_start[i] = f64::NAN;
        self.pool.release(slot, self.slot_mem[slot as usize]);
    }

    /// Schedule the speculation deadline for a freshly-started task if
    /// it qualifies: single-core `Array` work (gangs restart atomically
    /// and services never end, so duplicates race badly with both) with
    /// a streaming estimate already available for its kind. A
    /// `SpecCheck` fires at `speculate_factor ×` the kind's mean; a
    /// task still running then gets a duplicate launch.
    fn maybe_schedule_speculation(&mut self, now: Time, task: TaskId) {
        let i = task as usize;
        if self.soa.kind[i] != TaskSoa::KIND_ARRAY || self.soa.cores[i] != 1 {
            return;
        }
        let k = self.soa.kind[i] as usize;
        if self.spec_est_count[k] == 0 {
            return;
        }
        let deadline = now + self.speculate_factor * self.spec_est_mean[k];
        let epoch = self.epoch[i];
        self.queue.push(deadline, SimEv::SpecCheck { task, epoch });
    }

    /// Kill every speculative duplicate whose slot lives on `node`
    /// (node death sweeps duplicates too; the primaries, if elsewhere,
    /// keep running). O(tasks), only on node-lifecycle events.
    fn kill_duplicates_on(&mut self, now: Time, node: NodeId) {
        if !self.spec_active() {
            return;
        }
        for i in 0..self.spec_slot.len() {
            let s = self.spec_slot[i];
            if s != u32::MAX && self.pool.node_of(s) == node {
                self.kill_duplicate(now, i as u32);
            }
        }
    }

    /// Allocate every slot a task needs, all-or-nothing. The primary
    /// slot carries the task's memory; extra slots (cores > 1) carry
    /// none. On failure the allocations are rolled back in reverse so
    /// the pool's free-stack order is exactly as before the attempt.
    fn alloc_task(&mut self, tid: TaskId) -> Option<SlotId> {
        let mem_mb = self.soa.mem_mb[tid as usize];
        let cores = self.soa.cores[tid as usize];
        let primary = self.pool.alloc(mem_mb)?;
        self.slot_mem[primary as usize] = mem_mb;
        if cores > 1 {
            let start = self.extra_slots.len() as u32;
            for _ in 1..cores {
                match self.pool.alloc(0) {
                    Some(s) => {
                        self.slot_mem[s as usize] = 0;
                        self.extra_slots.push(s);
                    }
                    None => {
                        while self.extra_slots.len() as u32 > start {
                            let s = self.extra_slots.pop().expect("non-empty");
                            self.pool.release(s, 0);
                        }
                        self.pool.release(primary, mem_mb);
                        return None;
                    }
                }
            }
            self.extra_span[tid as usize] = (start, cores - 1);
        }
        if self.tracked() {
            self.kernel_alloc[tid as usize] = true;
        }
        Some(primary)
    }

    /// Undo a successful [`KernelCtx::alloc_task`] (gang rollback).
    /// Must be called in reverse allocation order.
    fn undo_alloc(&mut self, tid: TaskId, primary: SlotId) {
        if self.soa.cores[tid as usize] > 1 {
            let (start, len) = self.extra_span[tid as usize];
            debug_assert_eq!((start + len) as usize, self.extra_slots.len());
            for _ in 0..len {
                let s = self.extra_slots.pop().expect("non-empty");
                self.pool.release(s, 0);
            }
            self.extra_span[tid as usize] = (0, 0);
        }
        self.pool.release(primary, self.soa.mem_mb[tid as usize]);
        if self.tracked() {
            self.kernel_alloc[tid as usize] = false;
        }
    }

    /// All-or-nothing gang dispatch: allocate slots for every pending
    /// member of `job` in dispatch order (FIFO, or overlay order when
    /// an ordering combinator is active — the order the legacy sorted
    /// queue enumerated them in), roll everything back if any member
    /// fails.
    fn try_dispatch_gang(&mut self, job: JobId, launch: &mut LaunchFn) -> bool {
        let members = self.pending_members(job);
        let mut allocated: Vec<(TaskId, SlotId)> = Vec::with_capacity(members.len());
        for &t in &members {
            match self.alloc_task(t) {
                Some(p) => allocated.push((t, p)),
                None => {
                    for &(t2, p2) in allocated.iter().rev() {
                        self.undo_alloc(t2, p2);
                    }
                    return false;
                }
            }
        }
        for &t in &members {
            self.remove_pending(t);
        }
        for (t, p) in allocated {
            let l = launch(t, p);
            self.emit_launch(t, p, l);
        }
        true
    }

    fn emit_launch(&mut self, task: TaskId, slot: SlotId, l: Launch) {
        let ev = if l.via_stage {
            SimEv::Stage { task, slot }
        } else if self.has_preempt && self.evictions[task as usize] > 0 {
            SimEv::Resume { task, slot }
        } else {
            SimEv::Start { task, slot }
        };
        let mut at = l.at;
        if self.msg_active() {
            // In-flight control-message latency: probe RPCs for staged
            // launches, launch RPCs otherwise. Loss is drawn when the
            // event *fires* (so it also covers Starts pushed directly by
            // policies like Sparrow/YARN), latency when it is *sent*.
            let mean = if l.via_stage {
                self.msg.probe_latency_mean
            } else {
                self.msg.launch_latency_mean
            };
            if mean > 0.0 {
                at += self.msg_rng.exponential(mean);
            }
        }
        self.queue.push(at, ev);
    }

    /// `Start`/`Resume` event: record wait + trace (first start only),
    /// open the execution span and schedule the `End`. Returns whether
    /// this was the restart of a previously-evicted task (staged
    /// launches re-enter through `Start`, so the kernel detects resumes
    /// here rather than trusting the event variant).
    fn handle_start(&mut self, now: Time, task: TaskId, slot: SlotId) -> bool {
        let submit_at = self.soa.submit_at[task as usize];
        // An eviction resumes (partial work banked); a kill restarts
        // from scratch. Both are re-starts: wait and trace record were
        // taken at the first start. Aborted launches count as neither —
        // the task never started.
        let resumed = self.has_preempt && self.evictions[task as usize] > 0;
        let restart = resumed || (self.has_faults && self.kills[task as usize] > 0);
        if !restart {
            let wait = now - submit_at;
            self.waits.add(wait);
            self.wait_p50.add(wait);
            self.wait_p95.add(wait);
            self.wait_p99.add(wait);
            self.wait_sample.add(wait);
            if self.collect_trace {
                self.trace_idx[task as usize] = self.trace.len() as u32;
                self.trace.push(TraceRecord {
                    task,
                    node: self.pool.node_of(slot),
                    slot,
                    submit: submit_at,
                    start: now,
                    end: 0.0, // patched on End
                });
            }
        }
        if self.horizon.is_some() {
            self.win_start[task as usize] = now;
        }
        // A service runs until the horizon: it opens its span (and, under
        // preemption, its epoch/slot bookkeeping so it stays evictable)
        // but never schedules an `End`.
        let service = self.soa.is_service(task);
        if self.tracked() {
            let i = task as usize;
            self.epoch[i] += 1;
            self.span_start[i] = now;
            self.run_slot[i] = slot;
            if self.workload.tasks[i].preemptible && self.kernel_alloc[i] {
                self.rp_add(task);
            }
            let epoch = self.epoch[i];
            if !service {
                let mut end = now + self.remaining[i];
                if self.msg_active() && self.msg.completion_latency_mean > 0.0 {
                    // The completion notification travels back to the
                    // control plane: the task *finishes* on time but is
                    // *observed* late.
                    end += self.msg_rng.exponential(self.msg.completion_latency_mean);
                }
                self.queue.push(end, SimEv::End { task, slot, epoch });
                if self.msg_active()
                    && self.msg.dup_prob > 0.0
                    && self.msg_rng.chance(self.msg.dup_prob)
                {
                    // Duplicated completion notification. The first copy
                    // to fire completes the task and bumps the epoch;
                    // the second is recognisably stale (idempotent).
                    self.messages_duplicated += 1;
                    self.queue.push(end, SimEv::End { task, slot, epoch });
                }
                if self.spec_active() {
                    self.maybe_schedule_speculation(now, task);
                }
            }
        } else if !service {
            let end = now + self.soa.duration[task as usize];
            self.queue.push(end, SimEv::End { task, slot, epoch: 0 });
        }
        resumed
    }

    /// `End` event bookkeeping (before the policy's completion hook).
    fn handle_end(&mut self, now: Time, task: TaskId) {
        self.completed += 1;
        self.makespan = self.makespan.max(now);
        if self.horizon.is_some() {
            let i = task as usize;
            let cores = self.soa.cores[i] as f64;
            self.busy_core_seconds += cores * (now - self.win_start[i]);
            self.win_start[i] = f64::NAN;
        }
        if self.collect_trace {
            self.trace[self.trace_idx[task as usize] as usize].end = now;
        }
        if self.has_gang && self.soa.is_parallel(task) {
            // A completed member leaves its gang, so a later eviction
            // of the surviving members can still reassemble and
            // re-dispatch the remainder all-or-nothing.
            self.gang_total[self.soa.job[task as usize] as usize] -= 1;
        }
        if self.tracked() {
            let i = task as usize;
            if self.collect_trace {
                self.spans.push(ExecSpan {
                    task,
                    slot: self.run_slot[i],
                    start: self.span_start[i],
                    end: now,
                });
            }
            // The completed run's epoch moves on, so a duplicated
            // completion notification (MessagePlan) or a straggling
            // SpecEnd is recognisably stale — completion is idempotent.
            self.epoch[i] += 1;
            self.remaining[i] = 0.0;
            self.span_start[i] = f64::NAN;
            self.run_slot[i] = u32::MAX;
            self.kernel_alloc[i] = false;
            self.rp_remove(task);
        }
        if self.spec_active() {
            // Feed the streaming per-kind runtime estimate (true
            // durations, not observed spans — deterministic regardless
            // of message delays).
            let i = task as usize;
            let k = self.soa.kind[i] as usize;
            self.spec_est_count[k] += 1;
            let d = self.soa.duration[i];
            self.spec_est_mean[k] += (d - self.spec_est_mean[k]) / self.spec_est_count[k] as f64;
        }
    }

    /// Decrement dependents' indegrees; admit newly-ready tasks.
    /// Returns true if any task was admitted.
    fn propagate_deps(&mut self, task: TaskId) -> bool {
        let a = self.dep_off[task as usize] as usize;
        let b = self.dep_off[task as usize + 1] as usize;
        let mut any = false;
        for i in a..b {
            let d = self.dep_edges[i];
            self.indeg[d as usize] -= 1;
            if self.indeg[d as usize] == 0 && self.submitted[d as usize] {
                self.enqueue_ready(d);
                any = true;
            }
        }
        any
    }
}

/// The unified simulation driver. See the module docs for the event
/// loop / policy-hook contract.
pub struct Kernel;

impl Kernel {
    /// Run `policy` over `workload` on `cluster`, reusing `scratch`'s
    /// warm buffers, and assemble the [`RunResult`].
    pub fn run(
        policy: &mut dyn SchedPolicy,
        workload: &Workload,
        cluster: &ClusterSpec,
        options: &RunOptions,
        scratch: &mut SimScratch,
    ) -> RunResult {
        let n = workload.len();
        scratch.begin(cluster, n, options.collect_trace);
        if options.node_granular {
            scratch.pool.set_node_granular(true);
        }

        // One pass over the task list decides which optional mechanisms
        // this run needs, and packs the hot per-task fields into the
        // cache-linear SoA mirror; plain array workloads skip all of
        // the optional machinery.
        let mut has_deps = false;
        let mut has_gang = false;
        let mut has_multicore = false;
        let mut has_preempt = false;
        let mut has_service = false;
        let mut max_job = 0u32;
        for t in &workload.tasks {
            scratch.soa.push(t);
            has_deps |= !t.deps.is_empty();
            has_gang |= t.kind == JobKind::Parallel;
            has_multicore |= t.cores > 1;
            has_preempt |= t.preemptible;
            has_service |= t.kind == JobKind::Service;
            max_job = max_job.max(t.job);
        }
        let horizon = options.horizon;
        if let Some(h) = horizon {
            assert!(
                h.is_finite() && h > 0.0,
                "RunOptions.horizon must be finite and > 0, got {h}"
            );
        }
        // Hard check (not debug-only): running a Service task without a
        // horizon would silently simulate it as a batch task that
        // "completes" after its placeholder duration — wrong in every
        // metric. Workload::validate_for reports the same condition as
        // a recoverable error before a run reaches the kernel.
        assert!(
            !has_service || horizon.is_some(),
            "workload contains JobKind::Service tasks but RunOptions.horizon is None: \
             services never complete and require a horizon-bounded run"
        );

        if has_deps {
            scratch.indeg.resize(n, 0);
            scratch.submitted.resize(n, false);
            // CSR of dep -> dependents edges.
            scratch.dep_off.resize(n + 1, 0);
            for t in &workload.tasks {
                scratch.indeg[t.id as usize] = t.deps.len() as u32;
                for &d in &t.deps {
                    scratch.dep_off[d as usize + 1] += 1;
                }
            }
            for i in 0..n {
                let below = scratch.dep_off[i];
                scratch.dep_off[i + 1] += below;
            }
            let total = scratch.dep_off[n] as usize;
            scratch.dep_edges.resize(total, 0);
            let mut cursor: Vec<u32> = scratch.dep_off[..n].to_vec();
            for t in &workload.tasks {
                for &d in &t.deps {
                    let c = &mut cursor[d as usize];
                    scratch.dep_edges[*c as usize] = t.id;
                    *c += 1;
                }
            }
        }
        if has_gang {
            scratch.gang_total.resize(max_job as usize + 1, 0);
            scratch.gang_ready.resize(max_job as usize + 1, 0);
            for t in &workload.tasks {
                if t.kind == JobKind::Parallel {
                    scratch.gang_total[t.job as usize] += 1;
                }
            }
        }
        if has_multicore {
            scratch.extra_span.resize(n, (0, 0));
        }
        let has_faults = !options.faults.is_empty();
        debug_assert!(
            options.faults.validate().is_ok(),
            "invalid FaultPlan reached the kernel: {}",
            options.faults.validate().unwrap_err()
        );
        let has_degraded = options.degraded_active();
        if has_degraded {
            debug_assert!(
                options.messages.validate().is_ok(),
                "invalid MessagePlan reached the kernel: {}",
                options.messages.validate().unwrap_err()
            );
            assert!(
                options.detect_timeout.is_finite() && options.detect_timeout >= 0.0,
                "RunOptions.detect_timeout must be finite and >= 0, got {}",
                options.detect_timeout
            );
            assert!(
                options.heartbeat_period.is_finite() && options.heartbeat_period >= 0.0,
                "RunOptions.heartbeat_period must be finite and >= 0, got {}",
                options.heartbeat_period
            );
            assert!(
                options.speculate_factor.is_finite() && options.speculate_factor >= 0.0,
                "RunOptions.speculate_factor must be finite and >= 0, got {}",
                options.speculate_factor
            );
            if !options.messages.is_empty() {
                scratch.msg_attempt.resize(n, 0);
            }
            if options.detect_timeout > 0.0 {
                let n_nodes = cluster.n_nodes();
                scratch.node_failed_at.resize(n_nodes, f64::INFINITY);
                scratch.node_detected.resize(n_nodes, false);
                scratch.hb_seq.resize(n_nodes, 0);
            }
            if options.speculate_factor > 0.0 {
                scratch.spec_slot.resize(n, u32::MAX);
                scratch.spec_start.resize(n, f64::NAN);
            }
        }
        // Run-state tracking is shared by preemption, faults and the
        // degraded control plane.
        let track = has_preempt || has_faults || has_degraded;
        if track {
            scratch
                .remaining
                .extend(workload.tasks.iter().map(|t| t.duration));
            scratch.span_start.resize(n, f64::NAN);
            scratch.run_slot.resize(n, u32::MAX);
            scratch.epoch.resize(n, 0);
            scratch.evictions.resize(n, 0);
            scratch.kernel_alloc.resize(n, false);
            scratch.rp_pos.resize(n, u32::MAX);
        }
        if has_faults {
            scratch.kills.resize(n, 0);
            scratch.failed.resize(n, false);
        }
        if horizon.is_some() {
            scratch.win_start.resize(n, f64::NAN);
        }

        let SimScratch {
            soa,
            queue,
            pending,
            order,
            pool,
            slot_mem,
            trace,
            trace_idx,
            busy_until,
            indeg,
            dep_off,
            dep_edges,
            submitted,
            gang_total,
            gang_ready,
            extra_span,
            extra_slots,
            remaining,
            span_start,
            run_slot,
            epoch,
            evictions,
            kernel_alloc,
            rp_list,
            rp_pos,
            rp_buf,
            preempt_victims,
            kills,
            failed,
            kill_buf,
            spans,
            win_start,
            node_failed_at,
            node_detected,
            hb_seq,
            msg_attempt,
            spec_slot,
            spec_start,
            detect_latencies,
            wait_p50,
            wait_p95,
            wait_p99,
            wait_sample,
        } = scratch;
        let mut ctx = KernelCtx {
            workload,
            soa,
            queue,
            pending,
            order,
            pool,
            slot_mem,
            trace,
            trace_idx,
            busy_until,
            has_deps,
            indeg,
            dep_off,
            dep_edges,
            submitted,
            has_gang,
            gang_total,
            gang_ready,
            extra_span,
            extra_slots,
            has_preempt,
            remaining,
            span_start,
            run_slot,
            epoch,
            evictions,
            kernel_alloc,
            rp_list,
            rp_pos,
            rp_buf,
            spans,
            preempt_count: 0,
            has_faults,
            kills,
            failed,
            kill_count: 0,
            n_failed: 0,
            wasted_core_seconds: 0.0,
            has_degraded,
            msg: options.messages.clone(),
            msg_rng: Prng::new(options.messages.seed ^ MessagePlan::STREAM),
            detect_timeout: options.detect_timeout,
            speculate_factor: options.speculate_factor,
            node_failed_at,
            node_detected,
            hb_seq,
            msg_attempt,
            spec_slot,
            spec_start,
            detect_latencies,
            undetected_lost: 0.0,
            messages_lost: 0,
            messages_duplicated: 0,
            spec_launches: 0,
            spec_kills: 0,
            spec_est_count: [0; 3],
            spec_est_mean: [0.0; 3],
            horizon,
            win_start,
            busy_core_seconds: 0.0,
            collect_trace: options.collect_trace,
            completed: 0,
            makespan: 0.0,
            waits: Summary::new(),
            wait_p50,
            wait_p95,
            wait_p99,
            wait_sample,
        };

        // Seed submissions: batch tasks (t <= 0, array mode) go straight
        // to admission; everything else arrives through Arrive events.
        let mut batch = 0usize;
        for t in &workload.tasks {
            if t.submit_at <= 0.0 && !options.individual_submission {
                batch += 1;
                ctx.admit(t.id);
            } else {
                ctx.queue
                    .push(t.submit_at.max(0.0), SimEv::Arrive { task: t.id });
            }
        }
        if has_faults {
            // Seeded before the policy's first Tick, so at equal
            // timestamps a fault fires before same-time control-plane
            // and launch/end events: a failure beats a photo-finish
            // completion (deterministic, pessimistic). Out-of-range
            // node ids fail loudly in retire/restore.
            for e in &options.faults.events {
                let ev = match e.kind {
                    FaultKind::Fail => SimEv::NodeFail { node: e.node },
                    FaultKind::Drain => SimEv::NodeDrain { node: e.node },
                    FaultKind::Recover => SimEv::NodeRecover { node: e.node },
                };
                ctx.queue.push(e.at, ev);
            }
        }
        let hb_period = options.heartbeat_period;
        if has_degraded && options.detect_timeout > 0.0 && hb_period > 0.0 {
            // One self-rescheduling heartbeat stream per node, seeded
            // after the fault plan so a same-time fault fires first.
            // The stream runs for the whole workload (a down node's
            // beat fires but carries no liveness) and stops re-arming
            // once every task is resolved, so horizonless queues drain.
            for node in 0..cluster.n_nodes() as u32 {
                ctx.queue.push(hb_period, SimEv::Heartbeat { node });
            }
        }
        policy.on_submit(&mut ctx, batch);

        loop {
            if let Some(h) = horizon {
                // Windowed run: events past the horizon never execute
                // (queued launches/ends/ticks beyond it are simply
                // unobserved). Horizonless runs skip this peek entirely.
                if !matches!(ctx.queue.next_time(), Some(t) if t <= h) {
                    break;
                }
            }
            let Some((now, ev)) = ctx.queue.pop() else { break };
            match ev {
                SimEv::Arrive { task } => {
                    ctx.admit(task);
                    policy.on_arrive(&mut ctx, now, task);
                    if has_preempt {
                        preemption_pass(policy, &mut ctx, now, preempt_victims);
                    }
                }
                SimEv::Tick => {
                    policy.on_tick(&mut ctx, now);
                    if has_preempt {
                        preemption_pass(policy, &mut ctx, now, preempt_victims);
                    }
                    if ctx.completed + ctx.n_failed < n {
                        if let Some(interval) = policy.tick_interval() {
                            assert!(
                                !(ctx.queue.is_empty() && ctx.pool.busy_count() == 0),
                                "kernel stalled: {} of {n} tasks can never be \
                                 dispatched (cores/memory exceed cluster capacity?)",
                                n - ctx.completed - ctx.n_failed,
                            );
                            ctx.queue.push(now + interval, SimEv::Tick);
                        }
                    }
                }
                SimEv::Stage { task, slot } => policy.on_stage(&mut ctx, now, task, slot),
                SimEv::Start { task, slot } => {
                    if has_faults && ctx.dead_launch(task, slot) {
                        ctx.abort_launch(task, slot);
                        policy.on_slot_free(&mut ctx, now);
                    } else if ctx.msg_active()
                        && ctx.msg.loss_prob > 0.0
                        && ctx.launch_lost(task)
                    {
                        // Lost launch RPC: the slots stay held, the same
                        // event retries after a capped exponential
                        // backoff. Drawn at firing time so it also
                        // covers Starts pushed directly by policies.
                        let delay = ctx.msg.backoff_delay(ctx.msg_attempt[task as usize]);
                        ctx.queue.push(now + delay, SimEv::Start { task, slot });
                        policy.on_message_lost(&mut ctx, now, task, slot);
                    } else if ctx.handle_start(now, task, slot) {
                        // Staged launches of evicted tasks re-enter here,
                        // so resumes are detected rather than event-tagged.
                        policy.on_resume(&mut ctx, now, task, slot);
                    }
                }
                SimEv::Resume { task, slot } => {
                    if has_faults && ctx.dead_launch(task, slot) {
                        ctx.abort_launch(task, slot);
                        policy.on_slot_free(&mut ctx, now);
                    } else if ctx.msg_active()
                        && ctx.msg.loss_prob > 0.0
                        && ctx.launch_lost(task)
                    {
                        let delay = ctx.msg.backoff_delay(ctx.msg_attempt[task as usize]);
                        ctx.queue.push(now + delay, SimEv::Resume { task, slot });
                        policy.on_message_lost(&mut ctx, now, task, slot);
                    } else {
                        ctx.handle_start(now, task, slot);
                        policy.on_resume(&mut ctx, now, task, slot);
                    }
                }
                SimEv::Preempt { task, epoch } => {
                    // Stale if the victim completed or restarted since
                    // the request (its dispatch epoch moved on).
                    if has_preempt
                        && ctx.epoch[task as usize] == epoch
                        && ctx.run_slot[task as usize] != u32::MAX
                    {
                        ctx.execute_evict(now, task);
                    }
                }
                SimEv::End { task, slot, epoch } => {
                    if track && ctx.epoch[task as usize] != epoch {
                        continue; // stale End: the task was evicted or killed out of this run
                    }
                    if ctx.has_detection() {
                        if let Some(at) = ctx.end_deferral(task, slot) {
                            // The node died (unobserved): the completion
                            // can't reach the control plane. Defer to the
                            // suspicion instant — the detection kill wins
                            // the tie there, or the node recovered and
                            // the completion lands late (false alarm).
                            ctx.queue.push(at, SimEv::End { task, slot, epoch });
                            continue;
                        }
                    }
                    if ctx.spec_active() && ctx.spec_slot[task as usize] != u32::MAX {
                        // The primary won the race; the duplicate dies.
                        ctx.kill_duplicate(now, task);
                    }
                    ctx.handle_end(now, task);
                    if ctx.has_deps && ctx.propagate_deps(task) {
                        policy.on_deps_ready(&mut ctx, now);
                    }
                    if let Some(free_at) = policy.on_complete(&mut ctx, now, task, slot) {
                        ctx.queue.push(free_at, SimEv::SlotFree { slot });
                        if !ctx.extra_span.is_empty() {
                            let (s0, len) = ctx.extra_span[task as usize];
                            for k in 0..len {
                                let s = ctx.extra_slots[(s0 + k) as usize];
                                ctx.queue.push(free_at, SimEv::SlotFree { slot: s });
                            }
                        }
                    }
                }
                SimEv::SlotFree { slot } => {
                    ctx.pool.release(slot, ctx.slot_mem[slot as usize]);
                    policy.on_slot_free(&mut ctx, now);
                }
                SimEv::NodeFail { node } => {
                    if ctx.has_detection() {
                        // The failure is physical but not yet *observed*:
                        // capacity stays placeable (doomed launches
                        // included) until the detector fires
                        // `detect_timeout` later. No policy hook yet —
                        // the control plane has seen nothing.
                        let ni = node as usize;
                        ctx.node_failed_at[ni] = now;
                        ctx.node_detected[ni] = false;
                        ctx.hb_seq[ni] += 1;
                        let seq = ctx.hb_seq[ni];
                        ctx.queue
                            .push(now + ctx.detect_timeout, SimEv::Suspect { node, seq });
                    } else {
                        ctx.pool.retire_node(node);
                        ctx.collect_kill_victims(node, kill_buf);
                        for &t in kill_buf.iter() {
                            ctx.execute_kill(now, t);
                        }
                        ctx.kill_duplicates_on(now, node);
                        policy.on_node_fail(&mut ctx, now, node);
                    }
                }
                SimEv::NodeDrain { node } => {
                    ctx.pool.retire_node(node);
                    policy.on_node_drain(&mut ctx, now, node);
                }
                SimEv::NodeRecover { node } => {
                    if ctx.has_detection() {
                        let ni = node as usize;
                        let undetected =
                            ctx.node_failed_at[ni].is_finite() && !ctx.node_detected[ni];
                        ctx.hb_seq[ni] += 1; // stales any armed Suspect
                        ctx.node_failed_at[ni] = f64::INFINITY;
                        ctx.node_detected[ni] = false;
                        if undetected {
                            // False alarm: the node came back inside the
                            // detection window. Capacity was never
                            // retired, nothing was killed, and the
                            // control plane never saw the failure — the
                            // recovery costs (and announces) nothing.
                        } else {
                            ctx.pool.restore_node(node);
                            policy.on_node_recover(&mut ctx, now, node);
                        }
                    } else {
                        ctx.pool.restore_node(node);
                        policy.on_node_recover(&mut ctx, now, node);
                    }
                }
                SimEv::Heartbeat { node } => {
                    // Liveness cadence only: detection rides the Suspect
                    // timer armed at the (unobservable) failure instant,
                    // whose expiry models "detect_timeout elapsed without
                    // a heartbeat". Stops re-arming once the workload is
                    // resolved so horizonless runs drain their queue.
                    if ctx.completed + ctx.n_failed < n {
                        ctx.queue.push(now + hb_period, SimEv::Heartbeat { node });
                    }
                }
                SimEv::Suspect { node, seq } => {
                    let ni = node as usize;
                    if ctx.hb_seq[ni] != seq || !ctx.node_failed_at[ni].is_finite() {
                        continue; // false alarm: recovered inside the window
                    }
                    // Detection: retire the node and kill its tasks now,
                    // exactly as an instant-detection NodeFail would have
                    // at the failure instant — the difference (work run
                    // since then, doomed and invisible) is the price of
                    // late detection.
                    ctx.node_detected[ni] = true;
                    let failed_at = ctx.node_failed_at[ni];
                    ctx.detect_latencies.push(now - failed_at);
                    ctx.pool.retire_node(node);
                    ctx.collect_kill_victims(node, kill_buf);
                    for &t in kill_buf.iter() {
                        ctx.execute_kill_detected(now, t, failed_at);
                    }
                    ctx.kill_duplicates_on(now, node);
                    policy.on_node_suspected(&mut ctx, now, node);
                }
                SimEv::SpecCheck { task, epoch } => {
                    let i = task as usize;
                    // Stale if the task completed, was evicted or killed
                    // (epoch moved on); skipped if a duplicate already
                    // runs or the task is no longer running.
                    if ctx.epoch[i] == epoch
                        && ctx.run_slot[i] != u32::MAX
                        && ctx.spec_slot[i] == u32::MAX
                    {
                        ctx.launch_speculative(now, task);
                    }
                }
                SimEv::SpecEnd { task, slot, epoch } => {
                    let i = task as usize;
                    if ctx.epoch[i] != epoch || ctx.spec_slot[i] != slot {
                        continue; // stale: the primary won, or the duplicate was killed
                    }
                    if ctx.has_detection() {
                        let ni = ctx.pool.node_of(slot) as usize;
                        let fa = ctx.node_failed_at[ni];
                        if fa.is_finite() && !ctx.node_detected[ni] {
                            // Duplicate completed on a failed-undetected
                            // node: defer like a primary End would.
                            ctx.queue.push(
                                fa + ctx.detect_timeout,
                                SimEv::SpecEnd { task, slot, epoch },
                            );
                            continue;
                        }
                    }
                    // The duplicate wins: the primary's open span is the
                    // loser's, charged as duplicate overhead.
                    let primary = ctx.run_slot[i];
                    debug_assert!(primary != u32::MAX, "duplicate raced an idle task");
                    let cores = ctx.soa.cores[i] as f64;
                    ctx.wasted_core_seconds += cores * (now - ctx.span_start[i]);
                    if ctx.collect_trace {
                        ctx.spans.push(ExecSpan {
                            task,
                            slot: primary,
                            start: ctx.span_start[i],
                            end: now,
                        });
                    }
                    if horizon.is_some() {
                        // Close the primary's windowed span and hand the
                        // window over to the winning duplicate, so
                        // handle_end charges the duplicate's busy span.
                        ctx.busy_core_seconds += cores * (now - ctx.win_start[i]);
                        ctx.win_start[i] = ctx.spec_start[i];
                    }
                    if ctx.kernel_alloc[i] {
                        // Kill semantics for the loser's slot: immediate
                        // release (speculation is single-core, no extras).
                        ctx.pool.release(primary, ctx.slot_mem[primary as usize]);
                    }
                    // Adopt the duplicate's run as canonical, then
                    // complete through the ordinary path.
                    ctx.span_start[i] = ctx.spec_start[i];
                    ctx.run_slot[i] = slot;
                    ctx.kernel_alloc[i] = true;
                    ctx.spec_slot[i] = u32::MAX;
                    ctx.spec_start[i] = f64::NAN;
                    ctx.spec_kills += 1;
                    ctx.handle_end(now, task);
                    if ctx.has_deps && ctx.propagate_deps(task) {
                        policy.on_deps_ready(&mut ctx, now);
                    }
                    // The duplicate's slot is kernel-owned even under
                    // policies doing their own capacity bookkeeping
                    // (on_complete -> None), so it always releases.
                    let free_at = policy
                        .on_complete(&mut ctx, now, task, slot)
                        .unwrap_or(now);
                    ctx.queue.push(free_at, SimEv::SlotFree { slot });
                }
            }
        }

        if let Some(h) = horizon {
            // Window close: clip every still-open execution span to the
            // horizon — services by construction, plus batch tasks whose
            // `End` lies beyond the window.
            for t in &workload.tasks {
                let i = t.id as usize;
                let s = ctx.win_start[i];
                if s.is_nan() {
                    continue;
                }
                ctx.busy_core_seconds += t.cores as f64 * (h - s);
                if ctx.collect_trace {
                    ctx.trace[ctx.trace_idx[i] as usize].end = h;
                    if track {
                        ctx.spans.push(ExecSpan {
                            task: t.id,
                            slot: ctx.run_slot[i],
                            start: ctx.span_start[i],
                            end: h,
                        });
                    }
                }
            }
            if ctx.spec_active() {
                // Speculative duplicates still racing at the window
                // close: real occupancy (busy) that never produced a
                // unique completion — duplicate overhead (wasted)
                // either way.
                for i in 0..n {
                    let s = ctx.spec_slot[i];
                    if s == u32::MAX {
                        continue;
                    }
                    let cores = ctx.soa.cores[i] as f64;
                    let open = h - ctx.spec_start[i];
                    ctx.busy_core_seconds += cores * open;
                    ctx.wasted_core_seconds += cores * open;
                    if ctx.collect_trace {
                        ctx.spans.push(ExecSpan {
                            task: i as u32,
                            slot: s,
                            start: ctx.spec_start[i],
                            end: h,
                        });
                    }
                }
            }
        } else {
            // Hard check (not debug-only): an event-driven policy with an
            // undispatchable task drains the queue and would otherwise
            // return silently-truncated results in release builds. A
            // horizon-bounded run is exempt — the window closing before
            // every task completes is its normal outcome. Permanently
            // failed tasks (retry budget exhausted under a fault plan)
            // count as resolved.
            assert_eq!(
                ctx.completed + ctx.n_failed,
                n,
                "kernel finished with incomplete workload: {} of {n} tasks \
                 completed and {} failed (cores/memory exceed cluster \
                 capacity, a gang can never assemble, or every node holding \
                 the remaining work is down?)",
                ctx.completed,
                ctx.n_failed,
            );
        }
        let processors = cluster.total_cores();
        let events = ctx.queue.popped();
        // Retry histogram: hist[k] = tasks killed exactly k times, so
        // Σ k·hist[k] recovers the kill count (check_invariants pins
        // it). Empty without a fault plan.
        let retry_hist = if has_faults {
            let max_k = ctx.kills.iter().copied().max().unwrap_or(0) as usize;
            let mut hist = vec![0u64; max_k + 1];
            for &k in ctx.kills.iter() {
                hist[k as usize] += 1;
            }
            hist
        } else {
            Vec::new()
        };
        RunResult {
            scheduler: policy.label(),
            workload: workload.label.clone(),
            n_tasks: n as u64,
            processors,
            t_total: horizon.unwrap_or(ctx.makespan),
            t_job: workload.t_job_per_proc(processors),
            events,
            daemon_busy: policy.daemon_busy(),
            waits: ctx.waits,
            wait_p50: ctx.wait_p50.estimate(),
            wait_p95: ctx.wait_p95.estimate(),
            wait_p99: ctx.wait_p99.estimate(),
            wait_sample: ctx.wait_sample.sorted_sample(),
            preemptions: ctx.preempt_count,
            kills: ctx.kill_count,
            failed: ctx.n_failed as u64,
            completed: ctx.completed as u64,
            wasted_core_seconds: ctx.wasted_core_seconds,
            horizon,
            busy_core_seconds: ctx.busy_core_seconds,
            detection_latencies: std::mem::take(ctx.detect_latencies),
            undetected_lost_core_seconds: ctx.undetected_lost,
            messages_lost: ctx.messages_lost,
            messages_duplicated: ctx.messages_duplicated,
            spec_launches: ctx.spec_launches,
            spec_kills: ctx.spec_kills,
            retry_hist,
            trace: options.collect_trace.then(|| std::mem::take(ctx.trace)),
            spans: (options.collect_trace && track).then(|| std::mem::take(ctx.spans)),
        }
    }
}

/// One preemption decision round: the policy nominates victims, the
/// kernel validates and schedules the evictions. `victims` is the
/// warm scratch buffer, so steady-state passes allocate nothing.
fn preemption_pass(
    policy: &mut dyn SchedPolicy,
    ctx: &mut KernelCtx,
    now: Time,
    victims: &mut Vec<TaskId>,
) {
    if ctx.pending.is_empty() {
        return;
    }
    victims.clear();
    policy.on_preempt_candidates(ctx, now, victims);
    for &v in victims.iter() {
        ctx.request_preempt(now, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskSpec;

    /// Minimal zero-overhead policy used to exercise kernel mechanism
    /// in isolation (real policies live in `crate::sched`).
    struct InstantPolicy;

    impl SchedPolicy for InstantPolicy {
        fn label(&self) -> String {
            "Instant".into()
        }
        fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
            ctx.drain_fifo(&mut |_, _| Launch::start(0.0));
        }
        fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, _task: TaskId) {
            ctx.drain_fifo(&mut |_, _| Launch::start(now));
        }
        fn on_complete(
            &mut self,
            _ctx: &mut KernelCtx,
            now: Time,
            _task: TaskId,
            _slot: SlotId,
        ) -> Option<Time> {
            Some(now)
        }
        fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
            ctx.drain_fifo(&mut |_, _| Launch::start(now));
        }
        fn on_node_fail(&mut self, ctx: &mut KernelCtx, now: Time, _node: NodeId) {
            ctx.drain_fifo(&mut |_, _| Launch::start(now));
        }
        fn on_node_recover(&mut self, ctx: &mut KernelCtx, now: Time, _node: NodeId) {
            ctx.drain_fifo(&mut |_, _| Launch::start(now));
        }
    }

    fn cluster() -> ClusterSpec {
        ClusterSpec::homogeneous(2, 4, 32 * 1024, 2)
    }

    fn run(w: &Workload) -> RunResult {
        let mut scratch = SimScratch::new();
        Kernel::run(
            &mut InstantPolicy,
            w,
            &cluster(),
            &RunOptions::with_trace(),
            &mut scratch,
        )
    }

    #[test]
    fn array_workload_matches_ideal_arithmetic() {
        // 16 tasks of 3 s on 8 slots: two waves, 6 s.
        let tasks = (0..16).map(|i| TaskSpec::array(i, 0, 3.0)).collect();
        let w = Workload {
            tasks,
            label: "k".into(),
        };
        let r = run(&w);
        r.check_invariants().unwrap();
        assert!((r.t_total - 6.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert_eq!(r.trace.as_ref().unwrap().len(), 16);
    }

    #[test]
    fn dag_chain_serializes() {
        // 4-task chain of 2 s tasks: must take exactly 8 s even with
        // 8 free slots.
        let mut tasks: Vec<TaskSpec> = (0..4).map(|i| TaskSpec::array(i, 0, 2.0)).collect();
        for i in 1..4 {
            tasks[i as usize].deps = vec![i - 1];
        }
        let w = Workload {
            tasks,
            label: "chain".into(),
        };
        let r = run(&w);
        r.check_invariants().unwrap();
        assert!((r.t_total - 8.0).abs() < 1e-9, "t_total={}", r.t_total);
        // Dependency order respected in the trace.
        let trace = r.trace.as_ref().unwrap();
        let mut start = vec![0.0; 4];
        let mut end = vec![0.0; 4];
        for rec in trace {
            start[rec.task as usize] = rec.start;
            end[rec.task as usize] = rec.end;
        }
        for i in 1..4 {
            assert!(start[i] >= end[i - 1] - 1e-9, "task {i} started early");
        }
    }

    #[test]
    fn multicore_tasks_pack_slots() {
        // 4 tasks needing 4 cores each on 8 slots: two waves of two.
        let tasks = (0..4)
            .map(|i| {
                let mut t = TaskSpec::array(i, 0, 5.0);
                t.cores = 4;
                t
            })
            .collect();
        let w = Workload {
            tasks,
            label: "mc".into(),
        };
        let r = run(&w);
        r.check_invariants().unwrap();
        assert!((r.t_total - 10.0).abs() < 1e-9, "t_total={}", r.t_total);
    }

    #[test]
    fn gang_waits_for_all_members() {
        // Gang of 3 tasks (job 7) arriving at different times plus one
        // filler: the gang must not start before its last member
        // arrives, and must start together.
        let mut tasks: Vec<TaskSpec> = (0..3)
            .map(|i| {
                let mut t = TaskSpec::array(i, 7, 4.0);
                t.kind = JobKind::Parallel;
                t.submit_at = i as f64; // last member at t=2
                t
            })
            .collect();
        tasks.push(TaskSpec::array(3, 1, 1.0));
        let w = Workload {
            tasks,
            label: "gang".into(),
        };
        let r = run(&w);
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        let gang_starts: Vec<f64> = trace
            .iter()
            .filter(|t| t.task < 3)
            .map(|t| t.start)
            .collect();
        assert_eq!(gang_starts.len(), 3);
        for &s in &gang_starts {
            assert!((s - gang_starts[0]).abs() < 1e-9, "gang start skew");
            assert!(s >= 2.0 - 1e-9, "gang started before last member");
        }
        // The filler task backfilled at t=0 while the gang waited.
        let filler = trace.iter().find(|t| t.task == 3).unwrap();
        assert!(filler.start < 1e-9, "filler did not backfill");
    }

    #[test]
    fn gang_blocked_on_capacity_lets_backfill_through() {
        // Gang needs 6 of 8 slots but 4 are held by a long task; a
        // short 1-core task behind the gang backfills immediately.
        let mut tasks: Vec<TaskSpec> = Vec::new();
        let mut hog = TaskSpec::array(0, 0, 10.0);
        hog.cores = 4;
        tasks.push(hog);
        for i in 1..=6 {
            let mut t = TaskSpec::array(i, 9, 2.0);
            t.kind = JobKind::Parallel;
            tasks.push(t);
        }
        tasks.push(TaskSpec::array(7, 1, 1.0));
        let w = Workload {
            tasks,
            label: "gb".into(),
        };
        let r = run(&w);
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        let filler = trace.iter().find(|t| t.task == 7).unwrap();
        assert!(filler.start < 1e-9, "filler should backfill past the gang");
        for rec in trace.iter().filter(|t| (1..=6).contains(&t.task)) {
            assert!(rec.start >= 10.0 - 1e-9, "gang ran before capacity freed");
        }
    }

    #[test]
    fn late_arrivals_wait_for_submission() {
        let mut tasks: Vec<TaskSpec> = (0..4).map(|i| TaskSpec::array(i, 0, 1.0)).collect();
        tasks[3].submit_at = 50.0;
        let w = Workload {
            tasks,
            label: "arr".into(),
        };
        let r = run(&w);
        r.check_invariants().unwrap();
        let trace = r.trace.as_ref().unwrap();
        let late = trace.iter().find(|t| t.task == 3).unwrap();
        assert!((late.start - 50.0).abs() < 1e-9);
        assert!((r.t_total - 51.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "horizon")]
    fn service_without_horizon_panics_instead_of_running_as_batch() {
        let w = Workload {
            tasks: vec![TaskSpec::service(0, 0, 1)],
            label: "svc".into(),
        };
        run(&w); // RunOptions::with_trace() has no horizon
    }

    fn run_windowed(w: &Workload, horizon: f64) -> RunResult {
        let mut scratch = SimScratch::new();
        let options = RunOptions {
            collect_trace: true,
            horizon: Some(horizon),
            ..Default::default()
        };
        Kernel::run(&mut InstantPolicy, w, &cluster(), &options, &mut scratch)
    }

    #[test]
    fn services_occupy_slots_until_the_horizon() {
        // 8 slots: 4 one-core services pin half the cluster for the
        // whole 6 s window; 8 × 3 s batch tasks fill the other half in
        // two exact waves. Every core-second is productive: U = 1.
        let mut tasks: Vec<TaskSpec> =
            (0..4).map(|i| TaskSpec::service(i, i, 1)).collect();
        for i in 4..12 {
            tasks.push(TaskSpec::array(i, i, 3.0));
        }
        let w = Workload {
            tasks,
            label: "svc".into(),
        };
        let r = run_windowed(&w, 6.0);
        r.check_invariants().unwrap();
        assert_eq!(r.horizon, Some(6.0));
        assert!((r.t_total - 6.0).abs() < 1e-9);
        assert!(
            (r.busy_core_seconds - 48.0).abs() < 1e-9,
            "busy={}",
            r.busy_core_seconds
        );
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        let trace = r.trace.as_ref().unwrap();
        assert_eq!(trace.len(), 12);
        for rec in trace.iter().filter(|t| t.task < 4) {
            assert_eq!(rec.start, 0.0, "service {} starts immediately", rec.task);
            assert_eq!(rec.end, 6.0, "service {} clipped to horizon", rec.task);
        }
    }

    #[test]
    fn window_clips_batch_tasks_mid_flight() {
        // 12 × 3 s tasks on 8 slots, window of 4 s: the first wave
        // completes (24 core-s), the 4-task second wave runs [3, 4)
        // before the window closes (4 core-s).
        let tasks = (0..12).map(|i| TaskSpec::array(i, 0, 3.0)).collect();
        let w = Workload {
            tasks,
            label: "clip".into(),
        };
        let r = run_windowed(&w, 4.0);
        r.check_invariants().unwrap();
        assert!(
            (r.busy_core_seconds - 28.0).abs() < 1e-9,
            "busy={}",
            r.busy_core_seconds
        );
        assert!((r.utilization() - 28.0 / 32.0).abs() < 1e-9);
        let trace = r.trace.as_ref().unwrap();
        assert_eq!(trace.len(), 12, "every task started inside the window");
        assert_eq!(
            trace.iter().filter(|t| (t.end - 4.0).abs() < 1e-9).count(),
            4,
            "second wave clipped at the horizon"
        );
    }

    #[test]
    fn evicted_service_resumes_and_is_clipped_at_horizon() {
        // 2 slots pinned by preemptible services; a priority-1 1 s task
        // arrives at t=2. Both services are nominated, the foreground
        // task and one service reclaim the slots instantly, the other
        // service resumes at t=3. No idle core-seconds: U = 1.
        let mut tasks: Vec<TaskSpec> = (0..2)
            .map(|i| {
                let mut t = TaskSpec::service(i, i, 1);
                t.preemptible = true;
                t
            })
            .collect();
        let mut fg = TaskSpec::array(2, 2, 1.0);
        fg.submit_at = 2.0;
        fg.priority = 1;
        tasks.push(fg);
        let w = Workload {
            tasks,
            label: "svc-pre".into(),
        };
        let two_slots = ClusterSpec::homogeneous(1, 2, 32 * 1024, 1);
        let options = RunOptions {
            collect_trace: true,
            horizon: Some(10.0),
            ..Default::default()
        };
        let r = Kernel::run(
            &mut PreemptingInstant,
            &w,
            &two_slots,
            &options,
            &mut SimScratch::new(),
        );
        r.check_invariants().unwrap();
        assert_eq!(r.preemptions, 2);
        assert!(
            (r.busy_core_seconds - 20.0).abs() < 1e-9,
            "busy={}",
            r.busy_core_seconds
        );
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        // 2 evict spans + 1 foreground End span + 2 window-close spans.
        let spans = r.spans.as_ref().unwrap();
        assert_eq!(spans.len(), 5, "{spans:?}");
        for task in 0..2u32 {
            let last = spans
                .iter()
                .filter(|s| s.task == task)
                .map(|s| s.end)
                .fold(f64::NEG_INFINITY, f64::max);
            assert!((last - 10.0).abs() < 1e-9, "service {task} not clipped");
        }
        let fg_span = spans.iter().find(|s| s.task == 2).unwrap();
        assert!((fg_span.start - 2.0).abs() < 1e-9 && (fg_span.end - 3.0).abs() < 1e-9);
    }

    #[test]
    fn horizonless_runs_are_unchanged_by_the_window_machinery() {
        // The exact arithmetic of the pre-horizon kernel must hold, and
        // the result must carry no windowed accounting.
        let tasks = (0..16).map(|i| TaskSpec::array(i, 0, 3.0)).collect();
        let w = Workload {
            tasks,
            label: "k".into(),
        };
        let r = run(&w);
        r.check_invariants().unwrap();
        assert_eq!(r.horizon, None);
        assert_eq!(r.busy_core_seconds, 0.0);
        assert!((r.t_total - 6.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "kernel stalled")]
    fn stall_detection_fires_for_oversized_tasks() {
        struct TickedPolicy;
        impl SchedPolicy for TickedPolicy {
            fn label(&self) -> String {
                "Ticked".into()
            }
            fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
                ctx.push(0.0, SimEv::Tick);
            }
            fn tick_interval(&self) -> Option<Time> {
                Some(1.0)
            }
            fn on_tick(&mut self, ctx: &mut KernelCtx, now: Time) {
                ctx.drain_fifo(&mut |_, _| Launch::start(now));
            }
            fn on_complete(
                &mut self,
                _ctx: &mut KernelCtx,
                now: Time,
                _task: TaskId,
                _slot: SlotId,
            ) -> Option<Time> {
                Some(now)
            }
        }
        let mut t = TaskSpec::array(0, 0, 1.0);
        t.cores = 1000; // cluster has 8 slots
        let w = Workload {
            tasks: vec![t],
            label: "stall".into(),
        };
        let mut scratch = SimScratch::new();
        Kernel::run(
            &mut TickedPolicy,
            &w,
            &cluster(),
            &RunOptions::default(),
            &mut scratch,
        );
    }

    /// [`InstantPolicy`] plus priority preemption: nominate every
    /// running preemptible task whose priority is below the best
    /// pending priority.
    struct PreemptingInstant;

    impl SchedPolicy for PreemptingInstant {
        fn label(&self) -> String {
            "PreemptingInstant".into()
        }
        fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
            ctx.drain_fifo(&mut |_, _| Launch::start(0.0));
        }
        fn on_arrive(&mut self, ctx: &mut KernelCtx, now: Time, _task: TaskId) {
            ctx.drain_fifo(&mut |_, _| Launch::start(now));
        }
        fn on_complete(
            &mut self,
            _ctx: &mut KernelCtx,
            now: Time,
            _task: TaskId,
            _slot: SlotId,
        ) -> Option<Time> {
            Some(now)
        }
        fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
            ctx.drain_fifo(&mut |_, _| Launch::start(now));
        }
        fn on_preempt_candidates(
            &mut self,
            ctx: &mut KernelCtx,
            _now: Time,
            out: &mut Vec<TaskId>,
        ) {
            let w = ctx.workload();
            let best = ctx
                .pending_ids()
                .map(|t| w.tasks[t as usize].priority)
                .max()
                .unwrap_or(i32::MIN);
            let mut cands = Vec::new();
            ctx.preemptible_running(&mut cands);
            out.extend(
                cands
                    .into_iter()
                    .filter(|&v| w.tasks[v as usize].priority < best),
            );
        }
    }

    fn run_preempting(w: &Workload, cluster: &ClusterSpec) -> RunResult {
        let mut scratch = SimScratch::new();
        Kernel::run(
            &mut PreemptingInstant,
            w,
            cluster,
            &RunOptions::with_trace(),
            &mut scratch,
        )
    }

    #[test]
    fn preemption_splits_work_and_preserves_total() {
        // One slot. Background 10 s preemptible task; a priority-1
        // 1 s task arrives at t=2, evicts it, and the background task
        // resumes with exactly 8 s of work left.
        let one_slot = ClusterSpec::homogeneous(1, 1, 32 * 1024, 1);
        let mut bg = TaskSpec::array(0, 0, 10.0);
        bg.preemptible = true;
        let mut fg = TaskSpec::array(1, 1, 1.0);
        fg.submit_at = 2.0;
        fg.priority = 1;
        let w = Workload {
            tasks: vec![bg, fg],
            label: "pre".into(),
        };
        let r = run_preempting(&w, &one_slot);
        r.check_invariants().unwrap();
        assert_eq!(r.preemptions, 1);
        assert!((r.t_total - 11.0).abs() < 1e-9, "t_total={}", r.t_total);
        let spans = r.spans.as_ref().unwrap();
        assert_eq!(spans.len(), 3, "bg split into two spans + fg: {spans:?}");
        let bg_work: f64 = spans.iter().filter(|s| s.task == 0).map(|s| s.seconds()).sum();
        assert!((bg_work - 10.0).abs() < 1e-9, "no lost work: {bg_work}");
        // The foreground task ran immediately after the eviction.
        let fg_span = spans.iter().find(|s| s.task == 1).unwrap();
        assert!((fg_span.start - 2.0).abs() < 1e-9);
        assert!((fg_span.end - 3.0).abs() < 1e-9);
        // Trace still has one record per task, spanning first start to
        // final end.
        let trace = r.trace.as_ref().unwrap();
        assert_eq!(trace.len(), 2);
        let bg_rec = trace.iter().find(|t| t.task == 0).unwrap();
        assert!((bg_rec.start - 0.0).abs() < 1e-9);
        assert!((bg_rec.end - 11.0).abs() < 1e-9);
    }

    #[test]
    fn checkpoint_cost_delays_slot_release() {
        let one_slot = ClusterSpec::homogeneous(1, 1, 32 * 1024, 1);
        let mut bg = TaskSpec::array(0, 0, 10.0);
        bg.preemptible = true;
        bg.checkpoint_cost = 1.0;
        let mut fg = TaskSpec::array(1, 1, 1.0);
        fg.submit_at = 2.0;
        fg.priority = 1;
        let w = Workload {
            tasks: vec![bg, fg],
            label: "ckpt".into(),
        };
        let r = run_preempting(&w, &one_slot);
        r.check_invariants().unwrap();
        // Evict at 2, slot drains until 3, fg runs [3,4], bg [4,12].
        assert!((r.t_total - 12.0).abs() < 1e-9, "t_total={}", r.t_total);
        let spans = r.spans.as_ref().unwrap();
        let fg_span = spans.iter().find(|s| s.task == 1).unwrap();
        assert!((fg_span.start - 3.0).abs() < 1e-9, "{fg_span:?}");
    }

    #[test]
    fn gang_eviction_is_all_or_nothing() {
        // Two-slot cluster; a 2-member preemptible gang holds both
        // slots; a priority-1 arrival evicts the WHOLE gang, runs, and
        // the gang reassembles with its remaining work.
        let two_slots = ClusterSpec::homogeneous(1, 2, 32 * 1024, 1);
        let mut tasks: Vec<TaskSpec> = (0..2)
            .map(|i| {
                let mut t = TaskSpec::array(i, 7, 10.0);
                t.kind = JobKind::Parallel;
                t.preemptible = true;
                t
            })
            .collect();
        let mut fg = TaskSpec::array(2, 1, 1.0);
        fg.submit_at = 2.0;
        fg.priority = 1;
        tasks.push(fg);
        let w = Workload {
            tasks,
            label: "gangpre".into(),
        };
        let r = run_preempting(&w, &two_slots);
        r.check_invariants().unwrap();
        assert_eq!(r.preemptions, 2, "both members evicted");
        assert!((r.t_total - 11.0).abs() < 1e-9, "t_total={}", r.t_total);
        let spans = r.spans.as_ref().unwrap();
        // Each member: [0,2] then [3,11]; resumes synchronized.
        for task in 0..2u32 {
            let mut s: Vec<&ExecSpan> = spans.iter().filter(|s| s.task == task).collect();
            s.sort_by(|a, b| a.start.total_cmp(&b.start));
            assert_eq!(s.len(), 2);
            assert!((s[0].start - 0.0).abs() < 1e-9);
            assert!((s[0].end - 2.0).abs() < 1e-9);
            assert!((s[1].start - 3.0).abs() < 1e-9);
            assert!((s[1].end - 11.0).abs() < 1e-9);
        }
    }

    #[test]
    fn non_preemptible_tasks_are_refused() {
        // Background task is NOT preemptible (the foreground one is,
        // which activates the subsystem): the nomination is refused and
        // the arrival simply waits.
        let one_slot = ClusterSpec::homogeneous(1, 1, 32 * 1024, 1);
        let bg = TaskSpec::array(0, 0, 10.0);
        let mut fg = TaskSpec::array(1, 1, 1.0);
        fg.submit_at = 2.0;
        fg.priority = 1;
        fg.preemptible = true;
        let w = Workload {
            tasks: vec![bg, fg],
            label: "nopre".into(),
        };
        let r = run_preempting(&w, &one_slot);
        r.check_invariants().unwrap();
        assert_eq!(r.preemptions, 0);
        assert!((r.t_total - 11.0).abs() < 1e-9);
        let trace = r.trace.as_ref().unwrap();
        let fg_rec = trace.iter().find(|t| t.task == 1).unwrap();
        assert!((fg_rec.start - 10.0).abs() < 1e-9, "fg must wait");
    }

    #[test]
    fn preempt_scratch_reuse_matches_fresh() {
        // A preemption-heavy run through a warm scratch is bit-identical
        // to a fresh one, and a plain run AFTER a preempt run is
        // unaffected by the leftover buffers.
        let one_slot = ClusterSpec::homogeneous(1, 1, 32 * 1024, 1);
        let mut bg = TaskSpec::array(0, 0, 10.0);
        bg.preemptible = true;
        let mut fg = TaskSpec::array(1, 1, 1.0);
        fg.submit_at = 2.0;
        fg.priority = 1;
        let pre = Workload {
            tasks: vec![bg, fg],
            label: "pre".into(),
        };
        let plain = Workload {
            tasks: (0..8).map(|i| TaskSpec::array(i, 0, 1.0)).collect(),
            label: "plain".into(),
        };
        let mut scratch = SimScratch::new();
        for w in [&pre, &plain, &pre] {
            let warm = Kernel::run(
                &mut PreemptingInstant,
                w,
                &one_slot,
                &RunOptions::with_trace(),
                &mut scratch,
            );
            let fresh = run_preempting(w, &one_slot);
            assert_eq!(warm.t_total.to_bits(), fresh.t_total.to_bits());
            assert_eq!(warm.events, fresh.events);
            assert_eq!(warm.preemptions, fresh.preemptions);
            assert_eq!(warm.trace.as_ref().unwrap(), fresh.trace.as_ref().unwrap());
            assert_eq!(warm.spans, fresh.spans);
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_mechanisms() {
        // A deps+gang+multicore workload, then a plain array workload,
        // through one scratch: results must match fresh-scratch runs.
        let mut fancy: Vec<TaskSpec> = (0..12).map(|i| TaskSpec::array(i, 0, 2.0)).collect();
        for i in 4..8 {
            fancy[i].deps = vec![i as u32 - 4];
        }
        for i in 8..12 {
            fancy[i].kind = JobKind::Parallel;
            fancy[i].job = 5;
        }
        fancy[0].cores = 2;
        let fancy = Workload {
            tasks: fancy,
            label: "f".into(),
        };
        let plain = Workload {
            tasks: (0..20).map(|i| TaskSpec::array(i, 0, 1.0)).collect(),
            label: "p".into(),
        };
        let mut scratch = SimScratch::new();
        for w in [&fancy, &plain, &fancy] {
            let warm = Kernel::run(
                &mut InstantPolicy,
                w,
                &cluster(),
                &RunOptions::with_trace(),
                &mut scratch,
            );
            let fresh = run(w);
            assert_eq!(warm.t_total.to_bits(), fresh.t_total.to_bits());
            assert_eq!(warm.events, fresh.events);
            assert_eq!(warm.trace.as_ref().unwrap(), fresh.trace.as_ref().unwrap());
        }
    }

    // ---- fault-injection subsystem ------------------------------------------

    use crate::cluster::FaultPlan;

    fn run_faulted(w: &Workload, faults: FaultPlan, horizon: Option<f64>) -> RunResult {
        let mut scratch = SimScratch::new();
        let options = RunOptions {
            collect_trace: true,
            horizon,
            faults,
            ..Default::default()
        };
        Kernel::run(&mut InstantPolicy, w, &cluster(), &options, &mut scratch)
    }

    #[test]
    fn node_failure_kills_and_loses_work() {
        // 8 × 10 s tasks fill both nodes at t=0 (tasks 0–3 on node 0,
        // 4–7 on node 1). Node 1 dies at t=4: tasks 4–7 are killed with
        // their 4 s of progress LOST, requeue, and restart at t=10 when
        // node 0 frees — finishing at t=20 with a full re-run.
        let tasks = (0..8).map(|i| TaskSpec::array(i, 0, 10.0)).collect();
        let w = Workload {
            tasks,
            label: "churn".into(),
        };
        let r = run_faulted(&w, FaultPlan::none().fail(4.0, 1), None);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 4);
        assert_eq!(r.failed, 0);
        assert!((r.t_total - 20.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!(
            (r.wasted_core_seconds - 16.0).abs() < 1e-9,
            "wasted={}",
            r.wasted_core_seconds
        );
        // 8 completions + 4 kill spans.
        assert_eq!(r.spans.as_ref().unwrap().len(), 12);
        // The killed tasks' restarts went to node 0, never the dead one.
        let spans = r.spans.as_ref().unwrap();
        for s in spans.iter().filter(|s| s.start >= 4.0) {
            assert!(s.slot < 4, "span on dead node after failure: {s:?}");
        }
    }

    #[test]
    fn retry_budget_exhaustion_fails_tasks_permanently() {
        let tasks = (0..8)
            .map(|i| {
                let mut t = TaskSpec::array(i, 0, 10.0);
                t.max_retries = 0;
                t
            })
            .collect();
        let w = Workload {
            tasks,
            label: "fail".into(),
        };
        let r = run_faulted(&w, FaultPlan::none().fail(4.0, 1), None);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 4);
        assert_eq!(r.failed, 4, "budget of 0 means one kill is fatal");
        assert!((r.t_total - 10.0).abs() < 1e-9, "t_total={}", r.t_total);
        // 4 completions + 4 kill spans; every task started once.
        assert_eq!(r.spans.as_ref().unwrap().len(), 8);
        assert_eq!(r.trace.as_ref().unwrap().len(), 8);
    }

    #[test]
    fn drain_stops_placement_but_spares_running_work() {
        // 16 × 5 s tasks on 8 slots. Node 1 drains at t=2: the first
        // wave (8 tasks) finishes untouched at t=5, but the second wave
        // only gets node 0's 4 slots — two more waves of 4, done at 15.
        let tasks = (0..16).map(|i| TaskSpec::array(i, 0, 5.0)).collect();
        let w = Workload {
            tasks,
            label: "drain".into(),
        };
        let r = run_faulted(&w, FaultPlan::none().drain(2.0, 1), None);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 0, "drain kills nothing");
        assert_eq!(r.failed, 0);
        assert!((r.wasted_core_seconds - 0.0).abs() < 1e-9);
        assert!((r.t_total - 15.0).abs() < 1e-9, "t_total={}", r.t_total);
    }

    #[test]
    fn recovery_restores_failed_capacity() {
        // Node 1 dies at t=2 (killing tasks 4–7) and recovers at t=3:
        // the killed tasks restart there immediately and re-run their
        // full 10 s, ending at 13.
        let tasks = (0..8).map(|i| TaskSpec::array(i, 0, 10.0)).collect();
        let w = Workload {
            tasks,
            label: "recover".into(),
        };
        let r = run_faulted(&w, FaultPlan::none().fail(2.0, 1).recover(3.0, 1), None);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 4);
        assert_eq!(r.failed, 0);
        assert!((r.t_total - 13.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!(
            (r.wasted_core_seconds - 8.0).abs() < 1e-9,
            "wasted={}",
            r.wasted_core_seconds
        );
    }

    #[test]
    fn gang_dies_atomically_with_its_node() {
        // An 8-member gang spans both nodes; node 1 fails at t=3. ALL
        // members die (gang atomicity), wait for recovery at t=5, and
        // re-run together: done at 15.
        let tasks = (0..8)
            .map(|i| {
                let mut t = TaskSpec::array(i, 7, 10.0);
                t.kind = JobKind::Parallel;
                t
            })
            .collect();
        let w = Workload {
            tasks,
            label: "gangfail".into(),
        };
        let r = run_faulted(&w, FaultPlan::none().fail(3.0, 1).recover(5.0, 1), None);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 8, "whole gang killed, not just node 1's half");
        assert_eq!(r.failed, 0);
        assert!((r.t_total - 15.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!(
            (r.wasted_core_seconds - 24.0).abs() < 1e-9,
            "wasted={}",
            r.wasted_core_seconds
        );
        // Second starts are synchronized.
        let spans = r.spans.as_ref().unwrap();
        for s in spans.iter().filter(|s| s.start >= 4.0) {
            assert!((s.start - 5.0).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn services_restart_after_kills_without_consuming_a_budget() {
        // Tasks 0–3 (3 s batch) take node 0; the service lands on node
        // 1 and is killed at t=2. It has no free slot until the batch
        // wave ends at t=3, restarts there, and runs to the horizon.
        let mut tasks: Vec<TaskSpec> = (0..4).map(|i| TaskSpec::array(i, i, 3.0)).collect();
        tasks.push(TaskSpec::service(4, 4, 1));
        let w = Workload {
            tasks,
            label: "svc-fail".into(),
        };
        let r = run_faulted(&w, FaultPlan::none().fail(2.0, 1), Some(8.0));
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 1);
        assert_eq!(r.failed, 0, "services restart, they never fail");
        // Service busy [0,2) + [3,8): 7 s; batch 4 × 3 s = 12 s.
        assert!(
            (r.busy_core_seconds - 19.0).abs() < 1e-9,
            "busy={}",
            r.busy_core_seconds
        );
        assert!(
            (r.wasted_core_seconds - 2.0).abs() < 1e-9,
            "wasted={}",
            r.wasted_core_seconds
        );
        assert!(r.goodput_utilization() < r.utilization());
        let svc = r.trace.as_ref().unwrap().iter().find(|t| t.task == 4).unwrap();
        assert!((svc.end - 8.0).abs() < 1e-9, "service clipped to horizon");
    }

    #[test]
    fn launches_in_flight_toward_a_dead_node_abort_without_charge() {
        // Dispatch at t=0 with a 2 s launch delay; node 1 dies at t=1,
        // while 4 Starts are still in flight toward it. Those launches
        // abort silently — no kill, no waste — and the tasks re-dispatch
        // when node 0 frees at t=7 (start 9, end 14).
        struct DelayedPolicy;
        impl SchedPolicy for DelayedPolicy {
            fn label(&self) -> String {
                "Delayed".into()
            }
            fn on_submit(&mut self, ctx: &mut KernelCtx, _batch: usize) {
                ctx.drain_fifo(&mut |_, _| Launch::start(2.0));
            }
            fn on_complete(
                &mut self,
                _ctx: &mut KernelCtx,
                now: Time,
                _task: TaskId,
                _slot: SlotId,
            ) -> Option<Time> {
                Some(now)
            }
            fn on_slot_free(&mut self, ctx: &mut KernelCtx, now: Time) {
                ctx.drain_fifo(&mut |_, _| Launch::start(now + 2.0));
            }
        }
        let tasks = (0..8).map(|i| TaskSpec::array(i, 0, 5.0)).collect();
        let w = Workload {
            tasks,
            label: "abort".into(),
        };
        let mut scratch = SimScratch::new();
        let options = RunOptions {
            collect_trace: true,
            faults: FaultPlan::none().fail(1.0, 1),
            ..Default::default()
        };
        let r = Kernel::run(&mut DelayedPolicy, &w, &cluster(), &options, &mut scratch);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 0, "aborted launches are not kills");
        assert_eq!(r.failed, 0);
        assert!((r.wasted_core_seconds - 0.0).abs() < 1e-9);
        assert!((r.t_total - 14.0).abs() < 1e-9, "t_total={}", r.t_total);
        // Aborts leave no spans: 8 completion spans only.
        assert_eq!(r.spans.as_ref().unwrap().len(), 8);
    }

    #[test]
    fn failed_tasks_cascade_to_their_dependents() {
        // Task 0 (on a cluster-filling 8-core footprint) dies with a 0
        // budget; tasks 1 and 2 depend on it (2 on 1 transitively) and
        // can never run. Task 3 is independent and completes.
        let mut t0 = TaskSpec::array(0, 0, 10.0);
        t0.cores = 8;
        t0.max_retries = 0;
        let mut t1 = TaskSpec::array(1, 0, 1.0);
        t1.deps = vec![0];
        let mut t2 = TaskSpec::array(2, 0, 1.0);
        t2.deps = vec![1];
        let t3 = TaskSpec::array(3, 1, 1.0);
        let w = Workload {
            tasks: vec![t0, t1, t2, t3],
            label: "cascade".into(),
        };
        let r = run_faulted(&w, FaultPlan::none().fail(2.0, 1).recover(3.0, 1), None);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 1);
        assert_eq!(r.failed, 3, "task 0 plus both dependents");
        // Only tasks 0 (killed) and 3 ever started.
        assert_eq!(r.trace.as_ref().unwrap().len(), 2);
        // 1 completion (task 3) + 1 kill span.
        assert_eq!(r.spans.as_ref().unwrap().len(), 2);
    }

    #[test]
    fn empty_fault_plan_is_bit_identical_to_no_plan() {
        let tasks = (0..16).map(|i| TaskSpec::array(i, 0, 3.0)).collect();
        let w = Workload {
            tasks,
            label: "noop".into(),
        };
        let base = run(&w);
        let faulted = run_faulted(&w, FaultPlan::none(), None);
        assert_eq!(base.t_total.to_bits(), faulted.t_total.to_bits());
        assert_eq!(base.events, faulted.events);
        assert_eq!(base.trace, faulted.trace);
        assert_eq!(faulted.kills, 0);
        assert_eq!(faulted.failed, 0);
        assert_eq!(faulted.spans, None, "no tracking buffers without a plan");
    }

    #[test]
    fn fault_scratch_reuse_matches_fresh() {
        // A churn run through a warm scratch is bit-identical to a
        // fresh one, and a plain run AFTER it is unaffected.
        let churn = Workload {
            tasks: (0..8).map(|i| TaskSpec::array(i, 0, 10.0)).collect(),
            label: "churn".into(),
        };
        let plain = Workload {
            tasks: (0..8).map(|i| TaskSpec::array(i, 0, 1.0)).collect(),
            label: "plain".into(),
        };
        let plan = FaultPlan::none().fail(2.0, 1).recover(3.0, 1);
        let mut scratch = SimScratch::new();
        for (w, p) in [(&churn, &plan), (&plain, &FaultPlan::none()), (&churn, &plan)] {
            let options = RunOptions {
                collect_trace: true,
                faults: p.clone(),
                ..Default::default()
            };
            let warm = Kernel::run(&mut InstantPolicy, w, &cluster(), &options, &mut scratch);
            let fresh = run_faulted(w, p.clone(), None);
            assert_eq!(warm.t_total.to_bits(), fresh.t_total.to_bits());
            assert_eq!(warm.events, fresh.events);
            assert_eq!(warm.kills, fresh.kills);
            assert_eq!(warm.trace, fresh.trace);
            assert_eq!(warm.spans, fresh.spans);
        }
    }

    // ---- degraded control plane ----

    fn run_opts(w: &Workload, options: &RunOptions) -> RunResult {
        let mut scratch = SimScratch::new();
        Kernel::run(&mut InstantPolicy, w, &cluster(), options, &mut scratch)
    }

    #[test]
    fn detection_window_delays_the_kill_and_charges_undetected_work() {
        // 8 × 10 s tasks fill both nodes at t=0 (tasks 4–7 on node 1).
        // Node 1 dies at t=4 but with a 2 s detect timeout the kill
        // lands at t=6: each victim loses 6 s (vs 4 with instant
        // detection), of which the 2 s run after the physical failure
        // is undetected-doomed work. Retries start when node 0 frees
        // at t=10 and finish at t=20.
        let tasks = (0..8).map(|i| TaskSpec::array(i, 0, 10.0)).collect();
        let w = Workload {
            tasks,
            label: "detect".into(),
        };
        let options = RunOptions {
            collect_trace: true,
            faults: FaultPlan::none().fail(4.0, 1),
            ..Default::default()
        }
        .detection(2.0, 1.0);
        let r = run_opts(&w, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.kills, 4);
        assert_eq!(r.completed, 8);
        assert!((r.t_total - 20.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!((r.wasted_core_seconds - 24.0).abs() < 1e-9);
        assert!((r.undetected_lost_core_seconds - 8.0).abs() < 1e-9);
        assert_eq!(r.detection_latencies, vec![2.0]);
    }

    #[test]
    fn recovery_inside_the_window_is_a_zero_cost_false_alarm() {
        // Node 1 blips out at t=4 and returns at t=5, under a 2 s
        // detect timeout: the armed Suspect goes stale, nothing is
        // killed, and the run matches a failure-free one bit-for-bit.
        let tasks: Vec<TaskSpec> = (0..8).map(|i| TaskSpec::array(i, 0, 10.0)).collect();
        let w = Workload {
            tasks,
            label: "blip".into(),
        };
        let blip = RunOptions {
            collect_trace: true,
            faults: FaultPlan::none().fail(4.0, 1).recover(5.0, 1),
            ..Default::default()
        }
        .detection(2.0, 1.0);
        let clean = RunOptions::with_trace().detection(2.0, 1.0);
        let a = run_opts(&w, &blip);
        let b = run_opts(&w, &clean);
        a.check_invariants().unwrap();
        assert_eq!(a.kills, 0);
        assert_eq!(a.completed, 8);
        assert!((a.wasted_core_seconds - 0.0).abs() < 1e-9);
        assert!(a.detection_latencies.is_empty());
        assert_eq!(a.t_total.to_bits(), b.t_total.to_bits());
        assert_eq!(a.trace, b.trace);
    }

    #[test]
    fn lost_launches_retry_within_the_backoff_budget() {
        // 8 × 1 s tasks on 8 slots under 90 % launch loss with at most
        // 3 retries of 0.25/0.5/1.0 s: every task still completes, and
        // no start can slip past t = 1.75 (the attempt after the cap is
        // force-delivered), bounding the makespan.
        let tasks = (0..8).map(|i| TaskSpec::array(i, 0, 1.0)).collect();
        let w = Workload {
            tasks,
            label: "loss".into(),
        };
        let plan = MessagePlan::seeded(11).with_loss(0.9, 0.25, 1.0, 3);
        let options = RunOptions::with_trace().messages(plan);
        let r = run_opts(&w, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.completed, 8);
        assert!(r.messages_lost > 0, "0.9 loss over 8 launches never lost");
        assert!(r.t_total > 1.0, "a lost launch must delay its task");
        assert!(
            r.t_total <= 1.0 + 1.75 + 1e-9,
            "backoff cap exceeded: t_total={}",
            r.t_total
        );
        // Same seed, same draws: the perturbed run is deterministic.
        let again = run_opts(&w, &options);
        assert_eq!(r.t_total.to_bits(), again.t_total.to_bits());
        assert_eq!(r.messages_lost, again.messages_lost);
        assert_eq!(r.trace, again.trace);
    }

    #[test]
    fn duplicated_completions_are_idempotent() {
        // 90 % completion duplication: every duplicate End must hit the
        // epoch check, leaving exactly one completion per task and the
        // makespan of the unperturbed run.
        let tasks = (0..8).map(|i| TaskSpec::array(i, 0, 2.0)).collect();
        let w = Workload {
            tasks,
            label: "dup".into(),
        };
        let plan = MessagePlan::seeded(5).with_duplication(0.9);
        let options = RunOptions::with_trace().messages(plan);
        let r = run_opts(&w, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.completed, 8);
        assert!(r.messages_duplicated > 0, "0.9 dup over 8 Ends never fired");
        assert!((r.t_total - 2.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert_eq!(r.trace.as_ref().unwrap().len(), 8);
    }

    #[test]
    fn speculation_duplicate_loses_to_the_primary() {
        // Four 1 s calibration tasks seed the Array-class estimate;
        // a 10 s straggler submitted at t=2 then gets its SpecCheck at
        // t = 2 + 3 × 1 s = 5 and a duplicate launch. The primary ends
        // first (t=12), so the duplicate's 7 s span is pure overhead.
        let mut tasks: Vec<TaskSpec> = (0..4).map(|i| TaskSpec::array(i, 0, 1.0)).collect();
        let mut straggler = TaskSpec::array(4, 1, 10.0);
        straggler.submit_at = 2.0;
        tasks.push(straggler);
        let w = Workload {
            tasks,
            label: "spec".into(),
        };
        let options = RunOptions::with_trace().speculation(3.0);
        let r = run_opts(&w, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.completed, 5);
        assert_eq!(r.spec_launches, 1);
        assert_eq!(r.spec_kills, 1);
        assert!((r.t_total - 12.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!((r.wasted_core_seconds - 7.0).abs() < 1e-9);
        // 5 completion spans + 1 duplicate-overhead span.
        assert_eq!(r.spans.as_ref().unwrap().len(), 6);
    }

    #[test]
    fn speculation_duplicate_wins_when_the_primary_node_dies_undetected() {
        // A 4-core hog pins node 0 until t=4, four 1 s calibrations run
        // on node 1 (seeding the estimate), and a 10 s straggler
        // submitted at t=2 lands on node 1. Its duplicate (SpecCheck at
        // t=5) allocates on node 0, freed at t=4. Node 1 dies at t=11
        // with an 8 s detect window, so the primary's End (t=12) defers
        // past the duplicate's finish at t=15 — the duplicate wins, the
        // primary's 13 s span is charged as duplicate overhead, and the
        // detector fires at t=19 with nothing left to kill.
        let mut hog = TaskSpec::array(0, 0, 4.0);
        hog.cores = 4;
        let mut tasks = vec![hog];
        tasks.extend((1..5).map(|i| TaskSpec::array(i, 2, 1.0)));
        let mut straggler = TaskSpec::array(5, 3, 10.0);
        straggler.submit_at = 2.0;
        tasks.push(straggler);
        let w = Workload {
            tasks,
            label: "specwin".into(),
        };
        let options = RunOptions {
            collect_trace: true,
            faults: FaultPlan::none().fail(11.0, 1),
            ..Default::default()
        }
        .detection(8.0, 0.0)
        .speculation(3.0);
        let r = run_opts(&w, &options);
        r.check_invariants().unwrap();
        assert_eq!(r.completed, 6);
        assert_eq!(r.kills, 0, "the straggler moved before detection");
        assert_eq!(r.spec_launches, 1);
        assert_eq!(r.spec_kills, 1);
        assert!((r.t_total - 15.0).abs() < 1e-9, "t_total={}", r.t_total);
        assert!((r.wasted_core_seconds - 13.0).abs() < 1e-9);
        assert_eq!(r.detection_latencies, vec![8.0]);
        assert!((r.undetected_lost_core_seconds - 0.0).abs() < 1e-9);
    }

    #[test]
    fn inactive_degraded_options_are_bit_identical_to_plain() {
        // A seeded-but-empty message plan, zero detect timeout and zero
        // speculation factor must take the zero-cost bypass: identical
        // events, trace and timings, and no tracking buffers.
        let tasks = (0..16).map(|i| TaskSpec::array(i, 0, 3.0)).collect();
        let w = Workload {
            tasks,
            label: "bypass".into(),
        };
        let inactive = RunOptions::with_trace()
            .messages(MessagePlan::seeded(99))
            .detection(0.0, 0.0)
            .speculation(0.0);
        assert!(!inactive.degraded_active());
        let base = run(&w);
        let r = run_opts(&w, &inactive);
        assert_eq!(base.t_total.to_bits(), r.t_total.to_bits());
        assert_eq!(base.events, r.events);
        assert_eq!(base.trace, r.trace);
        assert_eq!(r.spans, None, "no tracking buffers when inactive");
        assert_eq!(r.messages_lost, 0);
        assert_eq!(r.spec_launches, 0);
    }
}
