//! Event queue + service stations for virtual-time simulation.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in seconds. Must stay finite; the queue asserts this.
pub type Time = f64;

struct Entry<T> {
    /// Packed ordering key: high 64 bits are the IEEE-754 bits of the
    /// (non-negative, finite) event time — monotone in the time value —
    /// and the low 64 bits the insertion sequence number. One u128
    /// comparison replaces a float partial_cmp plus a tie-break branch
    /// in the heap's hot sift loops, and encodes FIFO-among-ties
    /// determinism structurally.
    key: u128,
    payload: T,
}

#[inline]
fn pack_key(time: Time, seq: u64) -> u128 {
    debug_assert!(time >= 0.0);
    ((time.to_bits() as u128) << 64) | seq as u128
}

#[inline]
fn unpack_time(key: u128) -> Time {
    f64::from_bits((key >> 64) as u64)
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed key order.
        other.key.cmp(&self.key)
    }
}

/// Deterministic min-time event queue.
///
/// Events at equal times pop in insertion order. Popping also advances
/// `now()`; scheduling an event in the past panics (causality guard).
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    now: Time,
    popped: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    /// Empty queue at t = 0.
    pub fn new() -> Self {
        Self::with_capacity(1024)
    }

    /// Empty queue with a preallocated heap (avoids regrowth in the
    /// simulators' hot loops).
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
            now: 0.0,
            popped: 0,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events popped so far (simulation work metric).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no pending events.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at absolute time `at` (must be >= now and finite).
    pub fn push(&mut self, at: Time, payload: T) {
        assert!(at.is_finite(), "non-finite event time {at}");
        assert!(
            at >= self.now - 1e-9,
            "scheduling into the past: at={at} now={}",
            self.now
        );
        self.heap.push(Entry {
            key: pack_key(at.max(self.now), self.seq),
            payload,
        });
        self.seq += 1;
    }

    /// Schedule `payload` after a delay from now.
    pub fn push_after(&mut self, delay: Time, payload: T) {
        assert!(delay >= 0.0, "negative delay {delay}");
        let now = self.now;
        self.push(now + delay, payload);
    }

    /// Rewind to the empty t = 0 state while keeping the heap's backing
    /// allocation — the zero-alloc path for running many trials through
    /// one queue (see [`crate::sim::SimScratch`]). Behaviour after
    /// `reset` is bit-identical to a freshly constructed queue.
    pub fn reset(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.now = 0.0;
        self.popped = 0;
    }

    /// Pop the earliest event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(Time, T)> {
        let e = self.heap.pop()?;
        let time = unpack_time(e.key);
        debug_assert!(time >= self.now - 1e-9, "clock went backwards");
        self.now = time;
        self.popped += 1;
        Some((time, e.payload))
    }

    /// Peek at the time of the next event.
    pub fn next_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| unpack_time(e.key))
    }
}

/// A serial resource with FIFO queueing (e.g. the central scheduler
/// daemon's RPC/processing thread). Work items submitted at time `now`
/// with a service requirement start when the server frees up; the
/// returned value is the *completion* time.
#[derive(Clone, Debug, Default)]
pub struct ServiceStation {
    free_at: Time,
    busy_accum: Time,
    served: u64,
}

impl ServiceStation {
    /// Idle station.
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue work arriving at `now` needing `service` seconds; returns
    /// the completion time.
    #[inline]
    pub fn serve(&mut self, now: Time, service: Time) -> Time {
        debug_assert!(service >= 0.0, "negative service time");
        let start = now.max(self.free_at);
        self.free_at = start + service;
        self.busy_accum += service;
        self.served += 1;
        self.free_at
    }

    /// Time the station becomes idle.
    pub fn free_at(&self) -> Time {
        self.free_at
    }

    /// Total busy seconds accumulated.
    pub fn busy(&self) -> Time {
        self.busy_accum
    }

    /// Number of items served.
    pub fn served(&self) -> u64 {
        self.served
    }
}

/// c identical servers with a shared FIFO queue (e.g. a pool of dispatch
/// threads). Completion time = service start on the earliest-free server.
#[derive(Clone, Debug)]
pub struct MultiServer {
    free_at: Vec<Time>,
    busy_accum: Time,
    served: u64,
}

impl MultiServer {
    /// Pool of `c` idle servers.
    pub fn new(c: usize) -> Self {
        assert!(c > 0);
        Self {
            free_at: vec![0.0; c],
            busy_accum: 0.0,
            served: 0,
        }
    }

    /// Enqueue work arriving at `now` needing `service` seconds.
    pub fn serve(&mut self, now: Time, service: Time) -> Time {
        debug_assert!(service >= 0.0, "negative service time");
        // Earliest-free server; linear scan is fine for the small pools
        // we model (daemon thread counts, not cluster cores). total_cmp
        // keeps the selection total even if a free-time ever goes NaN —
        // partial_cmp().unwrap() here could panic mid-simulation.
        let idx = self
            .free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .expect("MultiServer has at least one server");
        let start = now.max(self.free_at[idx]);
        self.free_at[idx] = start + service;
        self.busy_accum += service;
        self.served += 1;
        self.free_at[idx]
    }

    /// Total busy seconds accumulated across all servers (same
    /// accounting as [`ServiceStation::busy`]).
    pub fn busy(&self) -> Time {
        self.busy_accum
    }

    /// Number of items served.
    pub fn served(&self) -> u64 {
        self.served
    }
}

/// Event payload shared by all scheduler simulators.
///
/// The seed gave each simulator its own private event enum, which made
/// every `Scheduler::run` allocate a fresh `EventQueue<Ev>`; one
/// concrete payload type lets [`crate::sim::SimScratch`] own a single
/// reusable queue across backends and trials. Variants cover the union
/// of the per-scheduler machines; each backend uses the subset it
/// needs.
#[derive(Clone, Copy, Debug)]
pub enum SimEv {
    /// A task's submission reaches the control plane (late arrival or
    /// individual-job submission).
    Arrive {
        /// Task id.
        task: u32,
    },
    /// Periodic control-plane pass: scheduling cycle (centralized),
    /// allocator offer round (Mesos) or NodeManager heartbeat (YARN).
    Tick,
    /// Intermediate launch stage bound to a slot (YARN's
    /// ApplicationMaster becoming ready).
    Stage {
        /// Task id.
        task: u32,
        /// Slot the task holds.
        slot: u32,
    },
    /// Task begins executing on its slot.
    Start {
        /// Task id.
        task: u32,
        /// Slot the task holds.
        slot: u32,
    },
    /// Task finished executing.
    End {
        /// Task id.
        task: u32,
        /// Slot the task holds.
        slot: u32,
        /// Dispatch epoch the `End` was scheduled under. The kernel
        /// bumps a task's epoch on every start, resume and eviction, so
        /// an `End` left in flight by a preemption is recognisably
        /// stale and ignored. Always 0 for workloads without
        /// preemptible tasks.
        epoch: u32,
    },
    /// Kernel-executed eviction of a running task (scheduled by
    /// [`crate::sim::KernelCtx::request_preempt`]). Carries the victim's
    /// dispatch epoch so an eviction that races a same-instant `End` or
    /// restart becomes a no-op instead of evicting the wrong run.
    Preempt {
        /// Task id.
        task: u32,
        /// Dispatch epoch the eviction was requested against.
        epoch: u32,
    },
    /// A previously-evicted task restarts on a slot (emitted instead of
    /// `Start` when the kernel's dispatch mechanism re-launches a
    /// preempted task; policies observe it via
    /// [`crate::sim::SchedPolicy::on_resume`]).
    Resume {
        /// Task id.
        task: u32,
        /// Slot the task restarts on.
        slot: u32,
    },
    /// Slot finished teardown and is reusable.
    SlotFree {
        /// Freed slot.
        slot: u32,
    },
    /// A node fails mid-run (scheduled from `RunOptions::faults`): its
    /// free slots retire, every task running there is killed — losing
    /// its non-checkpointed work — and killed tasks requeue through
    /// their retry budget.
    NodeFail {
        /// Failing node.
        node: u32,
    },
    /// A node drains mid-run: no new placement, running work finishes;
    /// slots park as they free.
    NodeDrain {
        /// Draining node.
        node: u32,
    },
    /// A retired node returns to service with its full slot complement.
    NodeRecover {
        /// Recovering node.
        node: u32,
    },
    /// A node's periodic heartbeat reaches the control plane. Only
    /// scheduled when `RunOptions::heartbeat_period > 0`; a node that
    /// is down when its heartbeat would fire emits nothing (the next
    /// recovery restarts the cadence).
    Heartbeat {
        /// Emitting node.
        node: u32,
    },
    /// The failure detector's timeout for a node expires
    /// (`detect_timeout` after its `NodeFail`). Carries the node's
    /// heartbeat sequence number at scheduling time: a recovery before
    /// the timeout bumps the sequence, turning the suspicion into a
    /// stale no-op (a false alarm that costs nothing).
    Suspect {
        /// Suspected node.
        node: u32,
        /// Heartbeat sequence the suspicion was raised against.
        seq: u32,
    },
    /// Speculation deadline for a task: fires `speculate_factor ×` the
    /// task class's streaming runtime estimate after its start. If the
    /// task (same epoch) is still running, the kernel launches a
    /// duplicate on a free slot.
    SpecCheck {
        /// Task id.
        task: u32,
        /// Dispatch epoch the deadline was scheduled against.
        epoch: u32,
    },
    /// A speculative duplicate finishes. Valid only while the epoch
    /// matches and the duplicate's slot is still registered — the
    /// kernel clears the registration whenever the primary wins or the
    /// duplicate is killed, so a stale `SpecEnd` is a no-op.
    SpecEnd {
        /// Task id.
        task: u32,
        /// Slot the duplicate ran on.
        slot: u32,
        /// Dispatch epoch at duplicate launch.
        epoch: u32,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, p)| p).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(5.0, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, p)| p).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(1.0, ());
        q.push(1.0, ());
        q.push(4.0, ());
        let mut last = 0.0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            assert_eq!(q.now(), t);
        }
        assert_eq!(q.popped(), 3);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push(1.0, ());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_time() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    fn push_after_uses_now() {
        let mut q = EventQueue::new();
        q.push(2.0, 0);
        q.pop();
        q.push_after(3.0, 1);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
    }

    #[test]
    fn station_serializes() {
        let mut s = ServiceStation::new();
        assert_eq!(s.serve(0.0, 2.0), 2.0);
        assert_eq!(s.serve(0.0, 2.0), 4.0); // queued behind the first
        assert_eq!(s.serve(10.0, 1.0), 11.0); // idle gap
        assert_eq!(s.busy(), 5.0);
        assert_eq!(s.served(), 3);
    }

    #[test]
    fn multiserver_parallelism() {
        let mut m = MultiServer::new(2);
        assert_eq!(m.serve(0.0, 4.0), 4.0);
        assert_eq!(m.serve(0.0, 4.0), 4.0); // second server
        assert_eq!(m.serve(0.0, 1.0), 5.0); // queues on earliest-free
    }

    #[test]
    fn multiserver_accounting_matches_station() {
        let mut m = MultiServer::new(2);
        m.serve(0.0, 4.0);
        m.serve(0.0, 4.0);
        m.serve(0.0, 1.0);
        assert_eq!(m.busy(), 9.0);
        assert_eq!(m.served(), 3);
        // Single-server pool degenerates to a ServiceStation.
        let mut one = MultiServer::new(1);
        let mut st = ServiceStation::new();
        for (now, svc) in [(0.0, 2.0), (1.0, 3.0), (10.0, 0.5)] {
            assert_eq!(one.serve(now, svc), st.serve(now, svc));
        }
        assert_eq!(one.busy(), st.busy());
        assert_eq!(one.served(), st.served());
    }

    #[test]
    fn reset_queue_behaves_like_fresh() {
        let mut q = EventQueue::new();
        q.push(3.0, 1u32);
        q.push(7.0, 2);
        q.pop();
        q.reset();
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.popped(), 0);
        assert!(q.is_empty());
        // Past-time pushes are legal again after reset.
        q.push(1.0, 9);
        assert_eq!(q.pop(), Some((1.0, 9)));
    }
}
