//! Indexed pending-queue structures: the kernel's O(1) pending list and
//! the incremental ordered ready-queue behind the `Ordered`/`Preemptive`
//! combinators.
//!
//! The pre-index kernel kept pending tasks in a `VecDeque` and paid
//! linear scans on the hot path: `take_task`/`try_dispatch` ran
//! `position()` over the whole queue per dispatch (quadratic for
//! event-driven policies like Sparrow that dispatch fresh arrivals from
//! the queue's back), and the `Ordered` combinator re-sorted the entire
//! deque before *every* dispatch opportunity (O(n log n) per event ⇒
//! ~O(n²·log n) per run). This module replaces both:
//!
//! * [`PendingList`] — an intrusive doubly-linked list over task ids.
//!   Membership, insertion and removal are O(1); FIFO iteration order is
//!   exactly the old deque's insertion order, so plain policies are
//!   bit-identical.
//! * [`OrderIndex`] — the incremental ordered ready-queue. Under
//!   `Order::Priority` it is one lazy-invalidation binary heap keyed by
//!   the packed `(priority desc, id asc)` total order. Under the
//!   wrapper's fairshare order `(usage asc, priority desc, id asc)` it
//!   is *two-level*: one static-keyed heap per user plus a per-user
//!   usage scalar. Because the fairshare component of the comparator
//!   depends on the task only through its user, a usage charge moves
//!   whole users relative to each other but never re-orders tasks
//!   within a user — so charging is O(1) and **no rebuild is ever
//!   needed**, which strictly subsumes the "rebuild only on reorders"
//!   requirement. Entries removed from the pending list elsewhere
//!   (gang dispatch, Sparrow's `take_task`) are invalidated lazily:
//!   they are skipped when they surface at a heap top.
//!
//! Equivalence contract: enumerating the index (repeated
//! [`OrderIndex::pop_front`]) yields exactly the permutation the legacy
//! eager `sort_queue`-style sort produced over the same pending set —
//! `tests/pool_equivalence.rs` pins this against an inline copy of the
//! legacy comparators, and [`OrderIndex::rebuild_eager`] keeps the
//! legacy full-sort path alive as the differential oracle (and as the
//! perf baseline the `scale` experiment's speedup is measured against).

use crate::workload::{TaskId, TaskSpec};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

const NIL: u32 = u32::MAX;

/// Intrusive doubly-linked pending list over dense task ids.
///
/// Replaces the kernel's pending `VecDeque`: same FIFO semantics, O(1)
/// `push_back`/`remove`/`contains`. Buffers are reused across runs via
/// [`PendingList::reset`] (see [`crate::sim::SimScratch`]).
#[derive(Debug, Default)]
pub struct PendingList {
    next: Vec<u32>,
    prev: Vec<u32>,
    in_q: Vec<bool>,
    head: u32,
    tail: u32,
    len: usize,
}

impl PendingList {
    /// Empty list.
    pub fn new() -> Self {
        Self {
            next: Vec::new(),
            prev: Vec::new(),
            in_q: Vec::new(),
            head: NIL,
            tail: NIL,
            len: 0,
        }
    }

    /// Rewind for a run of `n` tasks, keeping backing allocations.
    pub fn reset(&mut self, n: usize) {
        self.next.clear();
        self.next.resize(n, NIL);
        self.prev.clear();
        self.prev.resize(n, NIL);
        self.in_q.clear();
        self.in_q.resize(n, false);
        self.head = NIL;
        self.tail = NIL;
        self.len = 0;
    }

    fn ensure(&mut self, t: TaskId) {
        let need = t as usize + 1;
        if self.next.len() < need {
            self.next.resize(need, NIL);
            self.prev.resize(need, NIL);
            self.in_q.resize(need, false);
        }
    }

    /// Number of queued tasks.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True if `t` is queued. O(1).
    pub fn contains(&self, t: TaskId) -> bool {
        (t as usize) < self.in_q.len() && self.in_q[t as usize]
    }

    /// First queued task (FIFO head).
    pub fn first(&self) -> Option<TaskId> {
        (self.head != NIL).then_some(self.head)
    }

    /// Raw successor pointer of `t`.
    ///
    /// For a queued `t` this is the next queued task (or `None` at the
    /// tail). For a task *removed* from the list the pointer is left
    /// stale on purpose: it still leads (possibly through other removed
    /// tasks) to the first surviving successor in the old order, which
    /// is exactly what the kernel's FIFO drain needs to resume its walk
    /// after a gang dispatch removed the cursor. Callers must check
    /// [`PendingList::contains`] before trusting the target; the chain
    /// is only valid until the removed tasks are re-enqueued.
    pub fn next_of(&self, t: TaskId) -> Option<TaskId> {
        let n = self.next[t as usize];
        (n != NIL).then_some(n)
    }

    /// Append `t` at the back. O(1).
    pub fn push_back(&mut self, t: TaskId) {
        self.ensure(t);
        debug_assert!(!self.in_q[t as usize], "task {t} queued twice");
        let i = t as usize;
        self.next[i] = NIL;
        self.prev[i] = self.tail;
        if self.tail != NIL {
            self.next[self.tail as usize] = t;
        } else {
            self.head = t;
        }
        self.tail = t;
        self.in_q[i] = true;
        self.len += 1;
    }

    /// Remove `t` if queued; returns whether it was. O(1). The removed
    /// task's `next` pointer is intentionally left stale (see
    /// [`PendingList::next_of`]).
    pub fn remove(&mut self, t: TaskId) -> bool {
        if !self.contains(t) {
            return false;
        }
        let i = t as usize;
        let (p, n) = (self.prev[i], self.next[i]);
        if p != NIL {
            self.next[p as usize] = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.prev[n as usize] = p;
        } else {
            self.tail = p;
        }
        self.in_q[i] = false;
        self.len -= 1;
        true
    }

    /// Iterate queued tasks in FIFO order.
    pub fn iter(&self) -> PendingIter<'_> {
        PendingIter {
            list: self,
            cur: self.head,
        }
    }
}

/// FIFO iterator over a [`PendingList`].
pub struct PendingIter<'a> {
    list: &'a PendingList,
    cur: u32,
}

impl Iterator for PendingIter<'_> {
    type Item = TaskId;
    fn next(&mut self) -> Option<TaskId> {
        if self.cur == NIL {
            return None;
        }
        let t = self.cur;
        self.cur = self.list.next[t as usize];
        Some(t)
    }
}

/// Ordering discipline an [`OrderIndex`] maintains.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OrderMode {
    /// `(priority desc, id asc)` — `Order::Priority`.
    #[default]
    Priority,
    /// `(usage asc, priority desc, id asc)` — the `Ordered` wrapper's
    /// fairshare comparator (usage ties break by priority before id).
    Fairshare,
}

/// Pack `(priority desc, id asc)` into one `u64` so the heaps compare a
/// single integer: high word is the bit-inverted order-preserving map of
/// the i32 priority (smaller = higher priority), low word the id.
#[inline]
fn pack(priority: i32, id: TaskId) -> u64 {
    let inv_prio = !((priority as u32) ^ 0x8000_0000);
    ((inv_prio as u64) << 32) | id as u64
}

#[inline]
fn unpack_id(key: u64) -> TaskId {
    key as u32
}

type MinHeap = BinaryHeap<Reverse<u64>>;

/// The incremental ordered ready-queue (see module docs). Owned by the
/// kernel context and driven by the `Ordered` combinator; every buffer
/// is reused across runs through [`crate::sim::SimScratch`].
#[derive(Debug, Default)]
pub struct OrderIndex {
    active: bool,
    mode: OrderMode,
    /// Priority mode: the single global heap.
    prio_heap: MinHeap,
    /// Fairshare mode: dense-user remap (sorted distinct user ids),
    /// per-user usage and per-user heaps.
    user_ids: Vec<u32>,
    usage: Vec<f64>,
    user_heaps: Vec<MinHeap>,
    /// Entries popped during a walk that must survive it (blocked head,
    /// skipped gang members); re-pushed by [`OrderIndex::end_walk`].
    stash: Vec<u64>,
    /// Gangs already attempted during the current walk.
    pub(crate) tried_gangs: Vec<u32>,
    /// Scratch for [`OrderIndex::rebuild_eager`].
    rebuild_buf: Vec<TaskId>,
}

impl OrderIndex {
    /// Inactive index.
    pub fn new() -> Self {
        Self::default()
    }

    /// Rewind to the inactive state, keeping backing allocations.
    pub fn reset(&mut self) {
        self.active = false;
        self.prio_heap.clear();
        self.user_ids.clear();
        self.usage.clear();
        for h in &mut self.user_heaps {
            h.clear();
        }
        self.stash.clear();
        self.tried_gangs.clear();
        self.rebuild_buf.clear();
    }

    /// Whether an ordering overlay is active for the current run.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Active mode (meaningless while inactive).
    pub fn mode(&self) -> OrderMode {
        self.mode
    }

    /// Activate the overlay and seed it with the already-admitted
    /// pending set. For fairshare, the dense user remap is built from
    /// the whole task list so later arrivals hash to a known user.
    pub fn enable(&mut self, mode: OrderMode, tasks: &[TaskSpec], pending: &PendingList) {
        self.reset();
        self.active = true;
        self.mode = mode;
        if mode == OrderMode::Fairshare {
            self.user_ids.extend(tasks.iter().map(|t| t.user));
            self.user_ids.sort_unstable();
            self.user_ids.dedup();
            self.usage.resize(self.user_ids.len(), 0.0);
            if self.user_heaps.len() < self.user_ids.len() {
                self.user_heaps
                    .resize_with(self.user_ids.len(), MinHeap::new);
            }
        }
        for t in pending.iter() {
            self.push(t, tasks);
        }
    }

    #[inline]
    fn uidx(&self, user: u32) -> usize {
        self.user_ids
            .binary_search(&user)
            .expect("user present in the workload remap")
    }

    /// Accumulated fairshare usage of `user` (0 while inactive or under
    /// priority mode).
    pub fn usage_of(&self, user: u32) -> f64 {
        if self.active && self.mode == OrderMode::Fairshare {
            self.usage[self.uidx(user)]
        } else {
            0.0
        }
    }

    /// Charge fairshare usage. O(1): usage orders whole users, so no
    /// per-task re-keying (and no rebuild) is ever required.
    pub fn charge(&mut self, user: u32, core_seconds: f64) {
        if self.active && self.mode == OrderMode::Fairshare {
            let i = self.uidx(user);
            self.usage[i] += core_seconds;
        }
    }

    /// Index a newly-admitted pending task. O(log n).
    pub fn push(&mut self, task: TaskId, tasks: &[TaskSpec]) {
        if !self.active {
            return;
        }
        let spec = &tasks[task as usize];
        let key = Reverse(pack(spec.priority, task));
        match self.mode {
            OrderMode::Priority => self.prio_heap.push(key),
            OrderMode::Fairshare => {
                let u = self.uidx(spec.user);
                self.user_heaps[u].push(key);
            }
        }
    }

    /// Drop dead entries (tasks no longer pending) off a heap top.
    fn skim(heap: &mut MinHeap, pending: &PendingList) {
        while let Some(&Reverse(k)) = heap.peek() {
            if pending.contains(unpack_id(k)) {
                break;
            }
            heap.pop();
        }
    }

    /// First pending task in overlay order without consuming it.
    pub fn peek_front(&mut self, pending: &PendingList) -> Option<TaskId> {
        self.best_slot(pending)
            .map(|(_, key)| unpack_id(key))
    }

    /// Pop the first pending task in overlay order; the returned packed
    /// entry can be kept alive across a walk via
    /// [`OrderIndex::stash_entry`]. Amortized O(log n) (+O(users) under
    /// fairshare).
    pub fn pop_front(&mut self, pending: &PendingList) -> Option<u64> {
        let (slot, key) = self.best_slot(pending)?;
        let popped = match slot {
            None => self.prio_heap.pop(),
            Some(u) => self.user_heaps[u].pop(),
        };
        debug_assert_eq!(popped, Some(Reverse(key)));
        Some(key)
    }

    /// Locate the minimum live entry: `(owning heap, key)`. `None` heap
    /// slot means the global priority heap.
    fn best_slot(&mut self, pending: &PendingList) -> Option<(Option<usize>, u64)> {
        match self.mode {
            OrderMode::Priority => {
                Self::skim(&mut self.prio_heap, pending);
                self.prio_heap.peek().map(|&Reverse(k)| (None, k))
            }
            OrderMode::Fairshare => {
                // Two-level comparator: (usage[user], packed key). Users
                // with equal usage interleave their tasks exactly as the
                // flat legacy sort did, because the packed key carries
                // the remaining (priority desc, id asc) components.
                let mut best: Option<(usize, u64)> = None;
                for u in 0..self.user_ids.len() {
                    Self::skim(&mut self.user_heaps[u], pending);
                    let Some(&Reverse(k)) = self.user_heaps[u].peek() else {
                        continue;
                    };
                    let better = match best {
                        None => true,
                        Some((bu, bk)) => {
                            match self.usage[u].total_cmp(&self.usage[bu]) {
                                std::cmp::Ordering::Less => true,
                                std::cmp::Ordering::Greater => false,
                                std::cmp::Ordering::Equal => k < bk,
                            }
                        }
                    };
                    if better {
                        best = Some((u, k));
                    }
                }
                best.map(|(u, k)| (Some(u), k))
            }
        }
    }

    /// The head the `Preemptive` combinator targets: the maximal-
    /// priority pending task, tie-broken by *position in overlay order*
    /// — exactly what the legacy scan over the eagerly-sorted queue
    /// returned. O(log n) for priority mode, O(users) for fairshare.
    pub fn best_priority_head(
        &mut self,
        pending: &PendingList,
        tasks: &[TaskSpec],
    ) -> Option<TaskId> {
        match self.mode {
            // Overlay order IS (priority desc, id asc): the head is the
            // front of the index.
            OrderMode::Priority => self.peek_front(pending),
            // Overlay order is (usage, priority desc, id): each user's
            // heap top is that user's (max prio, min id) candidate; the
            // legacy scan picks, among max-priority tasks, the first in
            // (usage, id) order.
            OrderMode::Fairshare => {
                let mut best: Option<(i32, f64, TaskId)> = None;
                for u in 0..self.user_ids.len() {
                    Self::skim(&mut self.user_heaps[u], pending);
                    let Some(&Reverse(k)) = self.user_heaps[u].peek() else {
                        continue;
                    };
                    let id = unpack_id(k);
                    let prio = tasks[id as usize].priority;
                    let usage = self.usage[u];
                    let better = match best {
                        None => true,
                        Some((bp, bu, bid)) => {
                            prio > bp
                                || (prio == bp
                                    && (usage < bu || (usage == bu && id < bid)))
                        }
                    };
                    if better {
                        best = Some((prio, usage, id));
                    }
                }
                best.map(|(_, _, id)| id)
            }
        }
    }

    /// Keep a popped entry alive across the current walk (blocked head
    /// or skipped gang member that must stay indexed).
    pub fn stash_entry(&mut self, entry: u64) {
        self.stash.push(entry);
    }

    /// Finish a walk: re-push every stashed entry and clear the
    /// tried-gang scratch. Allocation-free after warm-up.
    pub fn end_walk(&mut self, tasks: &[TaskSpec]) {
        while let Some(e) = self.stash.pop() {
            match self.mode {
                OrderMode::Priority => self.prio_heap.push(Reverse(e)),
                OrderMode::Fairshare => {
                    let user = tasks[unpack_id(e) as usize].user;
                    let u = self.uidx(user);
                    self.user_heaps[u].push(Reverse(e));
                }
            }
        }
        self.tried_gangs.clear();
    }

    /// Sort `ids` into overlay order (the comparator the legacy eager
    /// sort applied to the whole queue). Used for order-sensitive
    /// snapshots (`pending_snapshot`, gang member collection).
    pub fn sort_ids(&self, ids: &mut [TaskId], tasks: &[TaskSpec]) {
        match self.mode {
            OrderMode::Priority => {
                ids.sort_unstable_by_key(|&t| pack(tasks[t as usize].priority, t));
            }
            OrderMode::Fairshare => {
                ids.sort_unstable_by(|&a, &b| {
                    let (ta, tb) = (&tasks[a as usize], &tasks[b as usize]);
                    let (ua, ub) = (self.usage_of(ta.user), self.usage_of(tb.user));
                    ua.total_cmp(&ub)
                        .then_with(|| pack(ta.priority, a).cmp(&pack(tb.priority, b)))
                });
            }
        }
    }

    /// Differential-oracle / perf-baseline path: discard the
    /// incrementally maintained entries and rebuild the index by a full
    /// `sort`-style pass over the live pending set — the cost profile of
    /// the legacy per-event `sort_queue`. The resulting walks are
    /// bit-identical to the incremental ones (the differential suite
    /// asserts it); only the per-event cost differs.
    pub fn rebuild_eager(&mut self, tasks: &[TaskSpec], pending: &PendingList) {
        if !self.active {
            return;
        }
        let mut buf = std::mem::take(&mut self.rebuild_buf);
        buf.clear();
        buf.extend(pending.iter());
        self.sort_ids(&mut buf, tasks);
        self.prio_heap.clear();
        for h in &mut self.user_heaps {
            h.clear();
        }
        for &t in &buf {
            self.push(t, tasks);
        }
        self.rebuild_buf = buf;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn list_fifo_order_and_o1_removal() {
        let mut l = PendingList::new();
        l.reset(8);
        for t in [3u32, 1, 5, 7, 0] {
            l.push_back(t);
        }
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![3, 1, 5, 7, 0]);
        assert!(l.remove(5));
        assert!(!l.remove(5));
        assert!(l.contains(7) && !l.contains(5));
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![3, 1, 7, 0]);
        assert!(l.remove(3)); // head
        assert!(l.remove(0)); // tail
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![1, 7]);
        assert_eq!(l.len(), 2);
        l.push_back(5); // re-enqueue at the back
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![1, 7, 5]);
    }

    #[test]
    fn removed_next_pointers_chain_to_the_first_survivor() {
        let mut l = PendingList::new();
        l.reset(6);
        for t in 0..6 {
            l.push_back(t);
        }
        // Remove a run in the middle; the stale chain from the first
        // removed node must lead to the first survivor (4).
        l.remove(1);
        l.remove(2);
        l.remove(3);
        let mut cur = l.next_of(1);
        while let Some(t) = cur {
            if l.contains(t) {
                break;
            }
            cur = l.next_of(t);
        }
        assert_eq!(cur, Some(4));
    }

    #[test]
    fn reset_rewinds_and_auto_grows() {
        let mut l = PendingList::new();
        l.reset(2);
        l.push_back(1);
        l.reset(2);
        assert!(l.is_empty() && !l.contains(1));
        l.push_back(9); // beyond the reset size: auto-grow
        assert!(l.contains(9));
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![9]);
    }

    #[test]
    fn pack_orders_priority_desc_then_id_asc() {
        assert!(pack(10, 5) < pack(0, 0), "higher priority first");
        assert!(pack(0, 1) < pack(0, 2), "id ascending within a level");
        assert!(pack(0, 99) < pack(-3, 0), "negative priorities last");
        assert!(pack(i32::MAX, 0) < pack(i32::MIN, 0));
    }

    fn specs(prios_users: &[(i32, u32)]) -> Vec<TaskSpec> {
        prios_users
            .iter()
            .enumerate()
            .map(|(i, &(p, u))| {
                let mut t = crate::workload::TaskSpec::array(i as u32, i as u32, 1.0);
                t.priority = p;
                t.user = u;
                t
            })
            .collect()
    }

    /// Drain the index to a Vec (entries are consumed).
    fn drain(ix: &mut OrderIndex, pending: &mut PendingList) -> Vec<TaskId> {
        let mut out = Vec::new();
        while let Some(e) = ix.pop_front(pending) {
            let t = e as u32;
            pending.remove(t);
            out.push(t);
        }
        out
    }

    #[test]
    fn priority_index_matches_sorted_order() {
        let tasks = specs(&[(0, 0), (5, 0), (5, 0), (2, 0), (9, 0)]);
        let mut pending = PendingList::new();
        pending.reset(tasks.len());
        for t in [4u32, 2, 0, 3, 1] {
            pending.push_back(t);
        }
        let mut ix = OrderIndex::new();
        ix.enable(OrderMode::Priority, &tasks, &pending);
        assert_eq!(drain(&mut ix, &mut pending), vec![4, 1, 2, 3, 0]);
    }

    #[test]
    fn fairshare_two_level_matches_flat_comparator() {
        // Users 0/1 with unequal usage; equal-usage users interleave by
        // (priority desc, id).
        let tasks = specs(&[(0, 0), (7, 1), (0, 1), (3, 0), (3, 2)]);
        let mut pending = PendingList::new();
        pending.reset(tasks.len());
        for t in 0..5 {
            pending.push_back(t);
        }
        let mut ix = OrderIndex::new();
        ix.enable(OrderMode::Fairshare, &tasks, &pending);
        ix.charge(1, 50.0);
        // usage: u0=0, u1=50, u2=0. Flat order by (usage, prio desc, id):
        // u0/u2 tie at 0 -> 3 (prio 3, id 3), 4 (prio 3, id 4), 0; then
        // user 1 -> 1 (prio 7), 2.
        assert_eq!(drain(&mut ix, &mut pending), vec![3, 4, 0, 1, 2]);
    }

    #[test]
    fn lazy_invalidation_skips_externally_removed_tasks() {
        let tasks = specs(&[(1, 0), (2, 0), (3, 0)]);
        let mut pending = PendingList::new();
        pending.reset(3);
        for t in 0..3 {
            pending.push_back(t);
        }
        let mut ix = OrderIndex::new();
        ix.enable(OrderMode::Priority, &tasks, &pending);
        pending.remove(2); // external removal (gang/take_task style)
        assert_eq!(drain(&mut ix, &mut pending), vec![1, 0]);
        // Re-enqueue: a fresh entry serves it again.
        pending.push_back(2);
        ix.push(2, &tasks);
        assert_eq!(drain(&mut ix, &mut pending), vec![2]);
    }

    #[test]
    fn stash_and_end_walk_preserve_entries() {
        let tasks = specs(&[(1, 0), (2, 0)]);
        let mut pending = PendingList::new();
        pending.reset(2);
        pending.push_back(0);
        pending.push_back(1);
        let mut ix = OrderIndex::new();
        ix.enable(OrderMode::Priority, &tasks, &pending);
        let e = ix.pop_front(&pending).unwrap();
        assert_eq!(e as u32, 1);
        ix.stash_entry(e); // blocked: keep it
        ix.end_walk(&tasks);
        assert_eq!(ix.peek_front(&pending), Some(1));
    }

    #[test]
    fn prop_index_drain_equals_legacy_sort() {
        // Differential oracle at the unit level: for random pending sets
        // and usage charges, draining the incremental index equals the
        // legacy flat sort with the wrapper comparators.
        let mut rng = Prng::new(0x0D7E);
        for case in 0..200u32 {
            let n = 1 + rng.below(24) as usize;
            let tasks = specs(
                &(0..n)
                    .map(|_| (rng.below(5) as i32, rng.below(4) as u32))
                    .collect::<Vec<_>>(),
            );
            let mut ids: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut ids);
            let keep = 1 + rng.below(n as u64) as usize;
            ids.truncate(keep);
            let mode = if case % 2 == 0 {
                OrderMode::Priority
            } else {
                OrderMode::Fairshare
            };
            let mut pending = PendingList::new();
            pending.reset(n);
            for &t in &ids {
                pending.push_back(t);
            }
            let mut ix = OrderIndex::new();
            ix.enable(mode, &tasks, &pending);
            let mut usage = vec![0.0f64; 4];
            for _ in 0..rng.below(4) {
                let u = rng.below(4) as u32;
                let c = rng.range_f64(0.0, 30.0);
                usage[u as usize] += c;
                ix.charge(u, c);
            }
            // Legacy flat sort.
            let mut expect = ids.clone();
            match mode {
                OrderMode::Priority => expect.sort_by(|&a, &b| {
                    tasks[b as usize]
                        .priority
                        .cmp(&tasks[a as usize].priority)
                        .then(a.cmp(&b))
                }),
                OrderMode::Fairshare => expect.sort_by(|&a, &b| {
                    let (ta, tb) = (&tasks[a as usize], &tasks[b as usize]);
                    usage[ta.user as usize]
                        .total_cmp(&usage[tb.user as usize])
                        .then(tb.priority.cmp(&ta.priority))
                        .then(a.cmp(&b))
                }),
            }
            let got = drain(&mut ix, &mut pending);
            assert_eq!(got, expect, "case {case} mode {mode:?}");
        }
    }
}
