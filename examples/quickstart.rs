//! Quickstart: the library in ~40 lines.
//!
//! 1. Build the paper's cluster and a short-task workload.
//! 2. Simulate it under the Slurm-like scheduler.
//! 3. Fit the latency model ΔT = t_s·n^α_s through the artifact-suite
//!    kernel path (and the direct rust fit for comparison).
//!
//! Run: `cargo run --release --example quickstart`

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::sched::{make_scheduler_scaled, RunOptions};
use sssched::util::fit::fit_power_law;
use sssched::workload::WorkloadBuilder;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A SuperCloud scaled down 4x (11 nodes × 32 cores), with daemon
    // costs scaled up 4x so the saturation knee — and hence the fitted
    // (t_s, α) — matches the paper's full-size cluster (DESIGN.md §11).
    let cluster = ClusterSpec::homogeneous(11, 32, 64 * 1024, 11);
    let p = cluster.total_cores();
    let scheduler = make_scheduler_scaled(SchedulerChoice::Slurm, 4);

    // Sweep tasks-per-processor at fixed 240 s of work per processor.
    let mut points = Vec::new();
    for n in [4u64, 8, 16, 48, 96, 240] {
        let t = 240.0 / n as f64;
        let workload = WorkloadBuilder::constant(t)
            .tasks(n * p)
            .label(format!("n{n}"))
            .build();
        let run = scheduler.run(&workload, &cluster, 42, &RunOptions::default());
        println!(
            "n={n:>3}  t={t:>6.2}s  T_total={:>8.1}s  ΔT={:>7.1}s  U={:.3}",
            run.t_total,
            run.delta_t(),
            run.utilization()
        );
        points.push((n as f64, run.delta_t()));
    }

    // Fit the paper's model through the artifact-suite kernel path.
    let ns: Vec<f64> = points.iter().map(|p| p.0).collect();
    let dts: Vec<f64> = points.iter().map(|p| p.1).collect();
    let mut suite = sssched::runtime::ArtifactSuite::load("artifacts")?;
    let fit = suite.powerlaw_fit(&[points])?[0];
    println!(
        "\nsuite fit ({}):  ΔT ≈ {:.2} · n^{:.2}   (R²={:.3})",
        suite.platform(),
        fit.t_s,
        fit.alpha_s,
        fit.r2
    );
    let rust_fit = fit_power_law(&ns, &dts);
    println!(
        "rust fit:  ΔT ≈ {:.2} · n^{:.2}   (R²={:.3})",
        rust_fit.t_s, rust_fit.alpha_s, rust_fit.r2
    );
    println!("\npaper (Table 10, Slurm): ΔT ≈ 2.2 · n^1.3");
    Ok(())
}
