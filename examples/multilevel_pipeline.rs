//! Multilevel scheduling pipeline (paper §5.3): take a pleasantly
//! parallel analytics campaign of thousands of 1-second tasks, run it
//! (a) submitted directly as a job array and (b) through the
//! LLMapReduce-style aggregator, on all three schedulers the paper
//! tested — and report the utilization recovery and ΔT reduction.
//!
//! Run: `cargo run --release --example multilevel_pipeline`

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::multilevel::{MapMode, Multilevel, MultilevelParams};
use sssched::sched::{make_scheduler, RunOptions, Scheduler};
use sssched::util::table::{fnum, Table};
use sssched::workload::WorkloadBuilder;

fn main() {
    // The paper's cluster, short-task campaign: n=240 tasks/processor of
    // 1 s each (the "rapid" set, the worst case of Figure 5).
    let cluster = ClusterSpec::supercloud();
    let p = cluster.total_cores();
    let workload = WorkloadBuilder::constant(1.0)
        .tasks(240 * p)
        .label("rapid-analytics")
        .build();
    println!(
        "workload: {} tasks x 1 s on {} cores ({} tasks/processor)\n",
        workload.len(),
        p,
        workload.len() as u64 / p
    );

    let mut table = Table::new(
        "regular vs multilevel (mimo) vs multilevel (siso)",
        &["scheduler", "mode", "T_total (s)", "ΔT (s)", "U", "ΔT reduction"],
    );

    for choice in [
        SchedulerChoice::Slurm,
        SchedulerChoice::GridEngine,
        SchedulerChoice::Mesos,
    ] {
        let inner = make_scheduler(choice);
        let base = inner.run(&workload, &cluster, 7, &RunOptions::default());
        base.check_invariants().unwrap();
        table.row(&[
            inner.name().into(),
            "regular array".into(),
            fnum(base.t_total),
            fnum(base.delta_t()),
            format!("{:.3}", base.utilization()),
            "1x".into(),
        ]);

        for (label, mode) in [("multilevel mimo", MapMode::Mimo), ("multilevel siso", MapMode::Siso)] {
            let ml = Multilevel::new(
                inner.as_ref(),
                MultilevelParams {
                    mode,
                    ..MultilevelParams::default()
                },
            );
            let run = ml.run(&workload, &cluster, 7, &RunOptions::default());
            run.check_invariants().unwrap();
            table.row(&[
                inner.name().into(),
                label.into(),
                fnum(run.t_total),
                fnum(run.delta_t()),
                format!("{:.3}", run.utilization()),
                format!("{:.0}x", base.delta_t() / run.delta_t().max(1e-9)),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "paper §5.3: multilevel scheduling lifts 1 s task utilization from <10% to ~90%,\n\
         with ΔT reductions of 30x (Slurm), 40x (Grid Engine), 100x (Mesos) at n=240;\n\
         siso mode pays the repeated map-application startup the paper warns about."
    );
}
