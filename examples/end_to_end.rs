//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! This is the repo's integration proof. It:
//!
//! 1. opens the analytics kernel through the artifact suite (L1/L2 →
//!    runtime) and calibrates how long one batch takes *under the same
//!    worker concurrency the benchmark will use*;
//! 2. runs a *realtime* mini-cluster — leader + P worker threads — where
//!    every task executes real analytics batches through the kernel,
//!    sweeping the task duration t at fixed total work per worker (the
//!    paper's benchmark design, §5) under an injected marginal scheduler
//!    latency t_s (L3 coordinator);
//! 3. measures wall-clock utilization U(t), fits ΔT = t_s·n^α through
//!    the suite's power-law kernel, and compares the measured curve with
//!    the paper's model U⁻¹ ≈ 1 + t_s/t — on real hardware, end to end.
//!
//! Run: `cargo run --release --example end_to_end`

use sssched::exec::{RealtimeCoordinator, RealtimeParams, RtTask, RtWork};
use sssched::model::u_constant_approx;
use sssched::runtime::ArtifactSuite;
use sssched::sched::RunResult;
use sssched::util::table::{fnum, Table};

/// Sized for the 2-core CI machine; bump on real hardware.
const WORKERS: usize = 2;
/// Injected marginal scheduler latency (the t_s knob), seconds.
const TS: f64 = 0.05;
/// Fixed work per worker (the paper's T_job = 240 s, scaled to ~2 s so
/// the example runs in seconds).
const T_JOB: f64 = 2.0;

fn coordinator(ts: f64) -> RealtimeCoordinator {
    RealtimeCoordinator::new(RealtimeParams {
        workers: WORKERS,
        dispatch_overhead: ts,
        artifacts_dir: Some("artifacts".into()),
    })
}

fn analytics_tasks(n_tasks: u32, batches: u32, nominal: f64) -> Vec<RtTask> {
    (0..n_tasks)
        .map(|id| RtTask {
            id,
            nominal,
            work: RtWork::Analytics {
                batches,
                seed: 0xE2E ^ id as u64,
            },
        })
        .collect()
}

/// Per-batch seconds measured from a run's trace.
fn batch_seconds(run: &RunResult, batches_per_task: u32) -> f64 {
    let trace = run.trace.as_ref().unwrap();
    let busy: f64 = trace.iter().map(|r| r.end - r.start).sum();
    busy / (trace.len() as f64 * batches_per_task as f64)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let suite = ArtifactSuite::load("artifacts")?;
    println!("kernel backend: {}", suite.platform());
    drop(suite); // workers own their backends

    // ---- 1. Calibrate the analytics batch under real concurrency
    // (zero injected overhead, all workers busy).
    let cal_run = coordinator(0.0).run(&analytics_tasks(WORKERS as u32 * 4, 256, 0.0))?;
    let batch_s = batch_seconds(&cal_run, 256);
    println!(
        "analytics batch under {WORKERS}-way concurrency: {:.3} ms\n",
        batch_s * 1e3
    );

    // ---- 2. Sweep task durations at fixed per-worker work.
    let mut table = Table::new(
        "realtime utilization vs task time (analytics payload via PJRT)",
        &["t (ms)", "n/worker", "tasks", "T_total (s)", "U measured", "U model", "thr (t/s)"],
    );
    let mut fit_points = Vec::new();
    for n_per_worker in [32u32, 16, 8, 4, 2] {
        let t_nominal = T_JOB / n_per_worker as f64;
        let batches = ((t_nominal / batch_s).round() as u32).max(1);
        let t_actual = batches as f64 * batch_s;
        let n_tasks = n_per_worker * WORKERS as u32;
        let run = coordinator(TS).run(&analytics_tasks(n_tasks, batches, t_actual))?;
        run.check_invariants()?;
        let u_model = u_constant_approx(TS, t_actual);
        table.row(&[
            fnum(t_actual * 1e3),
            n_per_worker.to_string(),
            n_tasks.to_string(),
            fnum(run.t_total),
            format!("{:.3}", run.utilization()),
            format!("{:.3}", u_model),
            fnum(run.n_tasks as f64 / run.t_total),
        ]);
        fit_points.push((n_per_worker as f64, run.delta_t()));
    }
    println!("{}", table.render());

    // ---- 3. Fit the latency model through the artifact-suite kernel.
    let mut suite = ArtifactSuite::load("artifacts")?;
    let fit = suite.powerlaw_fit(&[fit_points])?[0];
    println!(
        "power-law fit of the realtime runs: ΔT ≈ {:.3} · n^{:.2} (R²={:.3})",
        fit.t_s, fit.alpha_s, fit.r2
    );
    println!("injected marginal latency t_s = {TS} s/task");
    // Leader dispatch serializes across workers: per-worker marginal
    // cost ≈ TS (workers=2 → leader alternates), so fitted t_s should
    // land near TS and α near 1.
    if (fit.alpha_s - 1.0).abs() < 0.35 && fit.t_s > TS * 0.3 && fit.t_s < TS * 8.0 {
        println!("MODEL CONFIRMED: realtime behaviour matches the paper's latency model");
    } else {
        println!("warning: fit deviates from the injected overhead (noisy machine?)");
    }
    Ok(())
}
