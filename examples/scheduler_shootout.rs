//! Scheduler shootout: simulate the paper's Table 9 benchmark — four
//! schedulers × four constant-task-time sets × three trials on the
//! 1408-core virtual cluster — then fit the latency model (Table 10)
//! and print measured-vs-paper.
//!
//! Run: `cargo run --release --example scheduler_shootout`
//! Pass `--quick` for a scaled-down (352-core) fast run.

use sssched::cluster::ClusterSpec;
use sssched::config::SchedulerChoice;
use sssched::model::fit_from_runs;
use sssched::sched::{calibration, make_scheduler, RunOptions};
use sssched::util::table::{fnum, Table};
use sssched::workload::table9_sets;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (nodes, trials) = if quick { (11, 1) } else { (44, 3) };
    let cluster = ClusterSpec::homogeneous(nodes, 32, 64 * 1024, 22);
    let p = cluster.total_cores();
    println!(
        "cluster: {} nodes x 32 cores = {} slots, {} trial(s)\n",
        nodes, p, trials
    );

    let paper9 = calibration::paper_table9_runtimes();
    let mut t9 = Table::new(
        "Table 9 — runtimes (sim vs paper, s)",
        &["scheduler", "set", "t", "n", "sim mean", "paper mean", "ratio"],
    );
    let mut fits = Table::new(
        "Table 10 — model fit (sim vs paper)",
        &["scheduler", "t_s sim", "t_s paper", "alpha sim", "alpha paper", "R2"],
    );

    for (si, choice) in SchedulerChoice::paper_four().iter().enumerate() {
        let sched = make_scheduler(*choice);
        let mut runs = Vec::new();
        for (seti, set) in table9_sets().iter().enumerate() {
            let workload = set.workload(p);
            // Skip prohibitive runs like the paper (YARN rapid).
            if sched.projected_runtime(&workload, &cluster) > 3600.0 {
                t9.row(&[
                    sched.name().into(),
                    set.name.into(),
                    fnum(set.task_time),
                    set.tasks_per_proc.to_string(),
                    "abandoned".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
            let mut totals = Vec::new();
            for trial in 0..trials {
                let r = sched.run(&workload, &cluster, 1000 + trial, &RunOptions::default());
                r.check_invariants().expect("invariants");
                totals.push(r.t_total);
                runs.push(r);
            }
            let mean = totals.iter().sum::<f64>() / totals.len() as f64;
            let paper = paper9[si].1[seti];
            t9.row(&[
                sched.name().into(),
                set.name.into(),
                fnum(set.task_time),
                set.tasks_per_proc.to_string(),
                fnum(mean),
                paper.map(fnum).unwrap_or_else(|| "-".into()),
                paper
                    .map(|pv| format!("{:.2}", mean / pv))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        let fit = fit_from_runs(&runs);
        let pf = &calibration::paper_table10()[si];
        fits.row(&[
            sched.name().into(),
            fnum(fit.t_s),
            fnum(pf.t_s),
            format!("{:.2}", fit.alpha_s),
            format!("{:.2}", pf.alpha_s),
            format!("{:.3}", fit.r2),
        ]);
    }

    println!("{}", t9.render());
    println!("{}", fits.render());
}
